//! Property tests (propcheck) over coordinator invariants: admission,
//! KV slot lifecycle, bucket-ladder migration, packing round-trips, VM
//! totality.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use pangu_atlas_quant::atlas::perf_model::TokenInflation;
use pangu_atlas_quant::bench_suite::vm::{Op, Program};
use pangu_atlas_quant::coordinator::admission::{AdmissionQueue, AdmitConfig};
use pangu_atlas_quant::coordinator::cost::{AtlasCostModel, CostModel, SlotStepCostModel};
use pangu_atlas_quant::coordinator::kv::{
    Advance, KvConfig, KvSlots, PoolHeadroom, PrepareWrite, SlotState,
};
use pangu_atlas_quant::coordinator::request::Request;
use pangu_atlas_quant::coordinator::scheduler::{
    AdmitGate, LadderConfig, PreemptConfig, Scheduler, SchedulerConfig,
};
use pangu_atlas_quant::coordinator::slo::{SloPolicy, SloSnapshot};
use pangu_atlas_quant::quant::{int4, int8, Precision};
use pangu_atlas_quant::runtime::backend::MockBackend;
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};
use pangu_atlas_quant::util::propcheck::{check, check_vec, ensure, ensure_eq};

// ---------------------------------------------------------------------------
// KV slots
// ---------------------------------------------------------------------------

#[test]
fn prop_kv_slots_never_double_allocate() {
    check(
        "kv-unique-slots",
        100,
        0xA11,
        |rng| {
            let bucket = rng.range(1, 16);
            let n_alloc = rng.range(1, bucket);
            (bucket, n_alloc)
        },
        |&(bucket, n_alloc)| {
            let mut kv = KvSlots::new(bucket, 96);
            let mut seen = std::collections::HashSet::new();
            for _ in 0..n_alloc {
                let slot = kv.allocate(10).map_err(|e| e.to_string())?;
                ensure(seen.insert(slot), format!("slot {slot} allocated twice"))?;
                ensure(slot < bucket, "slot out of range")?;
            }
            ensure_eq(kv.active_count(), n_alloc, "active count")
        },
    );
}

#[test]
fn prop_kv_positions_bounded_by_window() {
    check(
        "kv-window-bound",
        100,
        0xB22,
        |rng| {
            let max_seq = rng.range(8, 64);
            let prompt = rng.range(1, max_seq - 1);
            let steps = rng.range(0, 2 * max_seq);
            (max_seq, prompt, steps)
        },
        |&(max_seq, prompt, steps)| {
            let mut kv = KvSlots::new(1, max_seq);
            let s = kv.allocate(prompt).map_err(|e| e.to_string())?;
            for _ in 0..steps {
                match kv.state(s) {
                    SlotState::Active { pos } => {
                        ensure(pos < max_seq, format!("pos {pos} >= window {max_seq}"))?;
                        let _ = kv.advance(s).map_err(|e| e.to_string())?;
                    }
                    SlotState::Finished { pos } => {
                        ensure(pos < max_seq, "finished past window")?;
                        break;
                    }
                    SlotState::Free => return Err("slot freed mid-run".into()),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_resize_preserves_every_occupant() {
    check(
        "kv-resize-carries-occupants",
        100,
        0xB55,
        |rng| {
            let bucket = rng.range(1, 12);
            // Random subset of slots stays occupied through the resize;
            // the rest are freed first. Spills (occupied slots above the
            // new bound) exercise the compaction path.
            let shape: Vec<bool> = (0..bucket).map(|_| rng.chance(0.6)).collect();
            let occupied = shape.iter().filter(|&&k| k).count();
            let new_bucket = rng.range(occupied.max(1), 16);
            (shape, new_bucket)
        },
        |(shape, new_bucket)| {
            let mut kv = KvSlots::new(shape.len(), 96);
            // Fill every slot first (allocation is first-free, so slot i
            // lands at position 10 + i), then free the non-kept ones.
            for i in 0..shape.len() {
                kv.allocate(10 + i).map_err(|e| e.to_string())?;
            }
            let mut want: BTreeMap<usize, SlotState> = BTreeMap::new();
            for (i, &keep) in shape.iter().enumerate() {
                if keep {
                    want.insert(i, SlotState::Active { pos: 10 + i });
                } else {
                    kv.finish(i).map_err(|e| e.to_string())?;
                    kv.release(i).map_err(|e| e.to_string())?;
                }
            }
            let moves = kv.resize(*new_bucket).map_err(|e| e.to_string())?;
            ensure_eq(kv.bucket(), *new_bucket, "table resized")?;
            ensure_eq(moves.len(), want.len(), "every occupant moved exactly once")?;
            ensure_eq(kv.occupied_count(), want.len(), "no occupant dropped")?;
            // Each move lands the old slot's exact state at the new index,
            // and no two moves share a destination.
            let mut dests = std::collections::HashSet::new();
            let mut sources = std::collections::HashSet::new();
            for &(old, new) in &moves {
                ensure(new < *new_bucket, "destination out of range")?;
                ensure(dests.insert(new), "two occupants share a destination")?;
                ensure(sources.insert(old), "slot moved twice")?;
                let state = want
                    .get(&old)
                    .ok_or_else(|| format!("moved slot {old} was not occupied"))?;
                ensure_eq(kv.state(new), *state, "position survives the move")?;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Bucket-ladder migration: randomized workloads over random ladders.
//
// The invariants the migration machinery must hold (it touches KV state
// correctness):
//   * per-slot decode positions stay strictly monotone across a migrate —
//     enforced *inside* MockBackend's position contract, which fails the
//     session loudly on any violation, so a clean run IS the assertion;
//   * no live slot is dropped — MockBackend::migrate rejects any plan that
//     drops a live slot, and completeness is asserted on the responses;
//   * finished-slot output is byte-identical to a fixed-bucket baseline
//     run at `max(buckets)`.
// ---------------------------------------------------------------------------

#[test]
fn prop_ladder_migration_invariants() {
    let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
    let run = |buckets: Vec<usize>,
               eval_every: usize,
               patience: usize,
               arrivals: &[(u8, usize)],
               cost: Arc<dyn CostModel>|
     -> Result<BTreeMap<u64, Vec<Vec<u32>>>, String> {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
        let mut be = MockBackend::new(64, 48, 96, script);
        let sched = Scheduler::new(
            &tk,
            SchedulerConfig {
                buckets,
                gate: AdmitGate::Continuous,
                ladder: LadderConfig {
                    eval_every,
                    shrink_patience: patience,
                    ..LadderConfig::default()
                },
                ..SchedulerConfig::default()
            }
            .with_cost(cost),
        );
        let mut queue = AdmissionQueue::new(AdmitConfig::with_wait(false, Duration::ZERO));
        // Request 0 is a slow_think anchor (30 tokens ≈ 60 pump ticks):
        // it keeps the session alive through every scheduled arrival.
        queue.push(mk_request(0, CotMode::SlowThink));
        let mut pumps = 0usize;
        let mut out: BTreeMap<u64, Vec<Vec<u32>>> = BTreeMap::new();
        sched
            .run(
                &mut be,
                &mut queue,
                &mut |q| {
                    pumps += 1;
                    for (i, &(tag, tick)) in arrivals.iter().enumerate() {
                        if tick == pumps {
                            q.push(mk_request(i as u64 + 1, modes[tag as usize]));
                        }
                    }
                },
                &mut |r| out.entry(r.id).or_default().push(r.tokens),
            )
            .map_err(|e| e.to_string())?;
        Ok(out)
    };
    check(
        "ladder-migration-invariants",
        30,
        0xAD47,
        |rng| {
            let sizes = [1usize, 2, 3, 4, 6, 8, 12, 16];
            let mut buckets: Vec<usize> = (0..rng.range(1, 4))
                .map(|_| sizes[rng.range(0, sizes.len() - 1)])
                .collect();
            buckets.sort_unstable();
            buckets.dedup();
            let eval_every = rng.range(1, 4);
            let patience = rng.range(1, 3);
            let arrivals: Vec<(u8, usize)> = (0..rng.range(1, 8))
                .map(|_| (rng.range(0, 2) as u8, rng.range(1, 40)))
                .collect();
            (buckets, eval_every, patience, arrivals)
        },
        |(buckets, eval_every, patience, arrivals)| {
            let adaptive = run(
                buckets.clone(),
                *eval_every,
                *patience,
                arrivals,
                Arc::new(SlotStepCostModel),
            )?;
            let atlas = run(
                buckets.clone(),
                *eval_every,
                *patience,
                arrivals,
                Arc::new(AtlasCostModel::openpangu_7b()),
            )?;
            let fixed = run(
                vec![*buckets.last().unwrap()],
                *eval_every,
                *patience,
                arrivals,
                Arc::new(SlotStepCostModel),
            )?;
            ensure_eq(adaptive.len(), arrivals.len() + 1, "every request answered")?;
            for (id, responses) in &adaptive {
                ensure_eq(responses.len(), 1, &format!("request {id} answered once"))?;
                ensure(!responses[0].is_empty(), format!("request {id} got tokens"))?;
            }
            ensure(
                adaptive == fixed,
                "adaptive outputs diverged from the fixed-bucket baseline",
            )?;
            ensure(
                atlas == fixed,
                "atlas-cost outputs diverged from the fixed-bucket baseline",
            )?;
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Paged KV block pool
// ---------------------------------------------------------------------------

/// Randomized slot churn (alloc / advance / finish+release / resize) over a
/// budgeted paged pool: no page double-mapped, the free list conserves
/// pages at every step, and a budgeted pool never overruns its capacity.
#[test]
fn prop_block_pool_never_double_maps_and_conserves_pages() {
    check(
        "block-pool-invariants",
        60,
        0x9A6E,
        |rng| {
            let bucket = rng.range(1, 8);
            let pages = rng.range(2, 24);
            let whole_window = rng.chance(0.3);
            let ops: Vec<u8> = (0..rng.range(4, 60)).map(|_| rng.range(0, 3) as u8).collect();
            (bucket, pages, whole_window, ops)
        },
        |(bucket, pages, whole_window, ops)| {
            let cfg = if *whole_window {
                KvConfig::whole_window(16, pages * 16)
            } else {
                KvConfig::paged(16, pages * 16)
            };
            let mut kv = KvSlots::with_config(*bucket, 96, cfg);
            let verify = |kv: &KvSlots| -> Result<(), String> {
                ensure(kv.pool_conserved(), "free-list conservation broken")?;
                // No page shared by two slots: the tables are disjoint.
                let mut seen = std::collections::HashSet::new();
                for slot in 0..kv.bucket() {
                    for &b in kv.blocks(slot) {
                        ensure(seen.insert(b), format!("page {b} mapped twice"))?;
                    }
                }
                ensure(
                    kv.pool_stats().used_pages <= *pages,
                    "budgeted pool overran its capacity",
                )
            };
            for (i, op) in ops.iter().enumerate() {
                match *op {
                    0 => {
                        // Admission (when the gate allows it).
                        let len = 10 + i % 30;
                        if kv.can_reserve(len) {
                            kv.allocate(len).map_err(|e| e.to_string())?;
                        }
                    }
                    1 => {
                        // Advance every active slot one step.
                        for slot in 0..kv.bucket() {
                            if matches!(kv.state(slot), SlotState::Active { .. }) {
                                let _ = kv.advance(slot).map_err(|e| e.to_string())?;
                            }
                        }
                    }
                    2 => {
                        // Retire the first occupied slot.
                        if let Some(slot) = (0..kv.bucket())
                            .find(|&s| !matches!(kv.state(s), SlotState::Free))
                        {
                            kv.finish(slot).map_err(|e| e.to_string())?;
                            kv.release(slot).map_err(|e| e.to_string())?;
                        }
                    }
                    _ => {
                        // Resize to a shape that still fits the occupants
                        // (exercises page re-owning across compaction).
                        let occ = kv.occupied_count().max(1);
                        let new_bucket = occ + i % 4;
                        kv.resize(new_bucket).map_err(|e| e.to_string())?;
                    }
                }
                verify(&kv)?;
            }
            // Drain: every page returns to the free list.
            kv.reset();
            ensure_eq(kv.pool_stats().used_pages, 0, "drained pool is empty")?;
            let stats = kv.pool_stats();
            ensure_eq(stats.allocs, stats.releases, "alloc/release balance")?;
            verify(&kv)
        },
    );
}

/// Randomized workloads: an amply budgeted paged scheduler produces
/// byte-identical outputs to the slot-granular (unbounded whole-window)
/// baseline, and a tightly budgeted one still answers every request
/// (pool exhaustion defers or truncates, never drops).
#[test]
fn prop_paged_scheduler_byte_identical_and_lossless() {
    let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
    // `up_front` queues every arrival before the session starts (used for
    // the tight-budget run, where pool exhaustion may truncate the anchor
    // and end the session before late pump ticks would fire).
    let run = |kv_cfg: Option<KvConfig>,
               bucket: usize,
               arrivals: &[(u8, usize)],
               up_front: bool|
     -> Result<(BTreeMap<u64, Vec<Vec<u32>>>, usize), String> {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
        let mut be = MockBackend::new(64, 48, 96, script);
        let mut cfg = SchedulerConfig::fixed(bucket, AdmitGate::Continuous);
        if let Some(kv_cfg) = kv_cfg {
            cfg = cfg.with_kv(kv_cfg);
        }
        let sched = Scheduler::new(&tk, cfg);
        let mut queue = AdmissionQueue::new(AdmitConfig::with_wait(false, Duration::ZERO));
        queue.push(mk_request(0, CotMode::SlowThink));
        if up_front {
            for (i, &(tag, _)) in arrivals.iter().enumerate() {
                queue.push(mk_request(i as u64 + 1, modes[tag as usize]));
            }
        }
        let mut pumps = 0usize;
        let mut out: BTreeMap<u64, Vec<Vec<u32>>> = BTreeMap::new();
        let report = sched
            .run(
                &mut be,
                &mut queue,
                &mut |q| {
                    pumps += 1;
                    if up_front {
                        return;
                    }
                    for (i, &(tag, tick)) in arrivals.iter().enumerate() {
                        if tick == pumps {
                            q.push(mk_request(i as u64 + 1, modes[tag as usize]));
                        }
                    }
                },
                &mut |r| out.entry(r.id).or_default().push(r.tokens),
            )
            .map_err(|e| e.to_string())?;
        Ok((out, report.deferred))
    };
    check(
        "paged-byte-identical",
        25,
        0x9B7F,
        |rng| {
            let bucket = rng.range(1, 6);
            let arrivals: Vec<(u8, usize)> = (0..rng.range(1, 6))
                .map(|_| (rng.range(0, 2) as u8, rng.range(1, 40)))
                .collect();
            let tight_pages = rng.range(4, 12);
            (bucket, arrivals, tight_pages)
        },
        |(bucket, arrivals, tight_pages)| {
            let (baseline, _) = run(None, *bucket, arrivals, false)?;
            // Ample budget: identical schedule, identical bytes.
            let (ample, deferred) =
                run(Some(KvConfig::paged(16, 4096)), *bucket, arrivals, false)?;
            ensure_eq(deferred, 0, "ample pool never defers")?;
            ensure(ample == baseline, "ample paged run diverged from baseline")?;
            // Tight budget: completeness only — every request answered
            // exactly once, with tokens (deferral/truncation, not loss).
            let (tight, _) = run(
                Some(KvConfig::paged(16, tight_pages * 16)),
                *bucket,
                arrivals,
                true,
            )?;
            ensure_eq(tight.len(), arrivals.len() + 1, "tight pool answered everyone")?;
            for (id, responses) in &tight {
                ensure_eq(responses.len(), 1, &format!("request {id} answered once"))?;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Preempt-and-recompute: the conservation suite ("no tokens lost, ever")
// ---------------------------------------------------------------------------

/// Randomized tight-pool workloads under the preempt policy: every response
/// is byte-identical to the same workload over an ample pool, nothing is
/// truncated, nothing is dropped or duplicated, and the mock backend's
/// replay-prefix contract (a restored slot replays exactly its pre-eviction
/// trace) is enforced on every restore — a contract violation fails the
/// session, so a clean run IS the assertion.
///
/// Pool sizing keeps truncation genuinely avoidable: every sequence peaks
/// at <= 4 pages (28-token prompt + 30-token trace), so any pool of >= 5
/// pages can always restore (replay + 1 headroom page), and each
/// preemption advances the starved sequence by at least one token — the
/// policy must convert that headroom into zero truncations.
#[test]
fn prop_preempt_tight_pool_byte_identical_and_lossless() {
    let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
    let run = |kv_cfg: Option<KvConfig>,
               bucket: usize,
               shapes: &[(u8, u8)]|
     -> Result<(BTreeMap<u64, Vec<(Vec<u32>, bool)>>, usize, usize), String> {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
        let mut be = MockBackend::new(64, 48, 96, script);
        let mut cfg = SchedulerConfig::fixed(bucket, AdmitGate::Continuous).with_preempt(
            PreemptConfig { enabled: true, max_per_seq: 64, restore_headroom_pages: 1 },
        );
        if let Some(kv_cfg) = kv_cfg {
            cfg = cfg.with_kv(kv_cfg);
        }
        let sched = Scheduler::new(&tk, cfg);
        let mut queue = AdmissionQueue::new(AdmitConfig::with_wait(false, Duration::ZERO));
        for (i, &(mode_tag, examples)) in shapes.iter().enumerate() {
            let ex: Vec<(Vec<u8>, Vec<u8>)> = (0..examples)
                .map(|_| (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]))
                .collect();
            queue.push(Request::new(i as u64, "7b-sim", "int8", modes[mode_tag as usize], ex));
        }
        let mut out: BTreeMap<u64, Vec<(Vec<u32>, bool)>> = BTreeMap::new();
        let report = sched
            .run(&mut be, &mut queue, &mut |_| {}, &mut |r| {
                out.entry(r.id).or_default().push((r.tokens, r.truncated));
            })
            .map_err(|e| e.to_string())?;
        // Conservation through the pool: every page the churn (admissions,
        // growth, evictions, restores) handed out came back.
        ensure_eq(
            report.kv_pages_allocated,
            report.kv_pages_released,
            "page conservation across preempt/restore churn",
        )?;
        ensure_eq(report.preemptions, be.restores + report.aborted, "every eviction restored")?;
        Ok((out, report.preemptions, report.recomputed_tokens))
    };
    let total_preemptions = std::cell::Cell::new(0usize);
    check(
        "preempt-no-tokens-lost",
        25,
        0x9E3E,
        |rng| {
            let bucket = rng.range(2, 4);
            // 0..=2 examples per request: 3 / 15 / 28 prompt tokens.
            let shapes: Vec<(u8, u8)> = (0..rng.range(2, 6))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8))
                .collect();
            // 5..=8 pages: tight enough to starve, never too tight to
            // restore a 4-page peak sequence plus headroom.
            let pages = rng.range(5, 8);
            (bucket, shapes, pages)
        },
        |(bucket, shapes, pages)| {
            let (ample, _, _) = run(None, *bucket, shapes)?;
            let (tight, preemptions, recomputed) =
                run(Some(KvConfig::paged(16, pages * 16)), *bucket, shapes)?;
            total_preemptions.set(total_preemptions.get() + preemptions);
            ensure_eq(tight.len(), shapes.len(), "every request answered")?;
            for (id, responses) in &tight {
                ensure_eq(responses.len(), 1, &format!("request {id} answered exactly once"))?;
                let (tokens, truncated) = &responses[0];
                ensure(!*truncated, format!("request {id} truncated under preemption"))?;
                let (ample_tokens, _) = &ample[id][0];
                ensure(
                    tokens == ample_tokens,
                    format!("request {id} diverged from the ample-pool run"),
                )?;
            }
            if preemptions == 0 {
                ensure_eq(recomputed, 0, "no recompute without a preemption")?;
            }
            Ok(())
        },
    );
    assert!(
        total_preemptions.get() > 0,
        "the generator never starved a pool: the property was vacuous"
    );
}

/// Block-pool conservation under direct preempt/restore churn at the
/// KvSlots layer: pages freed by an eviction grow the free list by exactly
/// the victim's table; a restore re-reserves exactly the replay-prefix
/// pages (the eviction's table, plus one page when the eviction happened
/// *at* a crossing); no page is ever double-mapped across the eviction
/// boundary; and after a full drain the pool's alloc/release ledger
/// balances to zero.
#[test]
fn prop_preempt_block_conservation_under_churn() {
    check(
        "preempt-block-conservation",
        60,
        0x9CAF,
        |rng| {
            let bucket = rng.range(1, 6);
            let pages = rng.range(3, 16);
            let ops: Vec<u8> = (0..rng.range(6, 70)).map(|_| rng.range(0, 3) as u8).collect();
            (bucket, pages, ops)
        },
        |(bucket, pages, ops)| {
            let mut kv =
                KvSlots::with_config(*bucket, 96, KvConfig::paged(16, pages * 16));
            // Parked ledger: (replay_len, pages freed at eviction).
            let mut parked: Vec<(usize, usize)> = Vec::new();
            let verify = |kv: &KvSlots| -> Result<(), String> {
                ensure(kv.pool_conserved(), "free-list conservation broken")?;
                let mut seen = std::collections::HashSet::new();
                for slot in 0..kv.bucket() {
                    for &b in kv.blocks(slot) {
                        ensure(
                            seen.insert(b),
                            format!("page {b} double-mapped across the eviction boundary"),
                        )?;
                    }
                }
                ensure(kv.pool_stats().used_pages <= *pages, "pool overran its budget")
            };
            for (i, op) in ops.iter().enumerate() {
                match *op {
                    0 => {
                        // Admission.
                        let len = 5 + i % 30;
                        if kv.can_reserve(len) {
                            kv.allocate(len).map_err(|e| e.to_string())?;
                        }
                    }
                    1 => {
                        // Advance every active slot; a starved slot is
                        // preempted (self-eviction: park its replay prefix
                        // and free its table).
                        for slot in 0..kv.bucket() {
                            let SlotState::Active { pos } = kv.state(slot) else {
                                continue;
                            };
                            match kv.try_advance(slot).map_err(|e| e.to_string())? {
                                Advance::Advanced | Advance::WindowExhausted => {}
                                Advance::PoolExhausted => {
                                    let free_pages = |kv: &KvSlots| {
                                        let s = kv.pool_stats();
                                        s.capacity_pages.unwrap() - s.used_pages
                                    };
                                    let freed = kv.block_count(slot);
                                    let free_before = free_pages(&kv);
                                    kv.release(slot).map_err(|e| e.to_string())?;
                                    let free_after = free_pages(&kv);
                                    // Eviction grows the free list by
                                    // exactly the victim's table.
                                    ensure_eq(
                                        free_after - free_before,
                                        freed,
                                        "pages freed by eviction",
                                    )?;
                                    // The replay prefix includes the token
                                    // whose page could not be backed.
                                    parked.push((pos + 1, freed));
                                }
                            }
                        }
                    }
                    2 => {
                        // Restore the parked head when pages + headroom
                        // allow; the re-reservation must equal the pages
                        // freed at eviction, plus exactly one page for the
                        // crossing the eviction was starved at.
                        let Some(&(replay, freed)) = parked.first() else {
                            continue;
                        };
                        if !kv.can_restore(replay, 1) {
                            continue;
                        }
                        parked.remove(0);
                        let used_before = kv.pool_stats().used_pages;
                        kv.allocate(replay).map_err(|e| e.to_string())?;
                        let reserved = kv.pool_stats().used_pages - used_before;
                        ensure_eq(
                            reserved,
                            freed + 1,
                            "restore re-reserves the evicted table + the starved page",
                        )?;
                    }
                    _ => {
                        // Retire the first occupied slot (pages recycle).
                        if let Some(slot) = (0..kv.bucket())
                            .find(|&s| !matches!(kv.state(s), SlotState::Free))
                        {
                            kv.finish(slot).map_err(|e| e.to_string())?;
                            kv.release(slot).map_err(|e| e.to_string())?;
                        }
                    }
                }
                verify(&kv)?;
            }
            // Drain: every page returns; the ledger balances even with
            // sequences still parked (a parked sequence holds zero pages).
            kv.reset();
            ensure_eq(kv.pool_stats().used_pages, 0, "drained pool is empty")?;
            let stats = kv.pool_stats();
            ensure_eq(stats.allocs, stats.releases, "alloc/release ledger balances")?;
            verify(&kv)
        },
    );
}

// ---------------------------------------------------------------------------
// Shared-prefix copy-on-write: the refcount conservation suite
// ---------------------------------------------------------------------------

/// Randomized refcount churn over a sharing-enabled pool: admissions drawn
/// from prefixes of one common token stream (heavy sharing at every
/// depth), decode steps through the CoW `prepare_write` hook, preemptions
/// that park-and-release, restores through the non-shared replay path, and
/// resizes. At every step the multiset of pages across live tables must
/// equal the pool's per-page refcounts (`pool_conserved` — no double-free,
/// no page mapped while free), the unique-page footprint must respect the
/// budget, releasing a sharer must drop exactly one ref per page (shared
/// pages survive for their sharers), and a write cursor must never sit on
/// a page with refcount > 1 after `prepare_write` says go.
#[test]
fn prop_cow_refcounts_conserved_under_churn() {
    const PT: usize = 8;
    let total_retains = std::cell::Cell::new(0usize);
    let total_forks = std::cell::Cell::new(0usize);
    check(
        "cow-refcount-conservation",
        60,
        0xC0DE,
        |rng| {
            let bucket = rng.range(2, 6);
            let pages = rng.range(3, 12);
            let ops: Vec<u8> = (0..rng.range(10, 80)).map(|_| rng.range(0, 5) as u8).collect();
            // Admission specs: a prefix length into the common stream,
            // plus a 30% chance the last token diverges (breaking the
            // equal-tail boundary claim, never the full-chunk match).
            let admits: Vec<(usize, bool)> = (0..rng.range(4, 20))
                .map(|_| (rng.range(1, 29), rng.chance(0.3)))
                .collect();
            (bucket, pages, ops, admits)
        },
        |(bucket, pages, ops, admits)| {
            let base: Vec<u32> = (0..64).map(|i| (i as u32 * 7 + 3) % 50).collect();
            let mut kv = KvSlots::with_config(
                *bucket,
                96,
                KvConfig::paged(PT, pages * PT).with_prefix_sharing(),
            );
            let verify = |kv: &KvSlots| -> Result<(), String> {
                ensure(kv.pool_conserved(), "refcount/table multiset conservation broken")?;
                ensure(
                    kv.pool_stats().used_pages <= *pages,
                    "pool overran its unique-page budget",
                )
            };
            // Releasing (retire or preempt) drops exactly one ref per
            // mapped page; a page with surviving sharers must stay live.
            let checked_release = |kv: &mut KvSlots, slot: usize| -> Result<(), String> {
                let before: Vec<(usize, usize)> =
                    kv.blocks(slot).iter().map(|&b| (b, kv.page_refs(b))).collect();
                kv.release(slot).map_err(|e| e.to_string())?;
                for (b, refs) in before {
                    ensure_eq(kv.page_refs(b), refs - 1, "release drops exactly one ref")?;
                    if refs > 1 {
                        ensure(kv.page_refs(b) >= 1, "shared page freed under its sharers")?;
                    }
                }
                Ok(())
            };
            let mut admit_cursor = 0usize;
            let mut parked: Vec<usize> = Vec::new();
            for (i, op) in ops.iter().enumerate() {
                match *op {
                    0 => {
                        // Shared admission (cycled through the spec list).
                        let (len, diverge) = admits[admit_cursor % admits.len()];
                        admit_cursor += 1;
                        let mut ids = base[..len].to_vec();
                        if diverge {
                            ids[len - 1] = 100 + admit_cursor as u32;
                        }
                        if kv.can_admit_shared(&ids) {
                            kv.allocate_shared(&ids).map_err(|e| e.to_string())?;
                        }
                    }
                    1 => {
                        // One decode step per active slot, through the CoW
                        // hook exactly as the scheduler drives it.
                        for slot in 0..kv.bucket() {
                            if !matches!(kv.state(slot), SlotState::Active { .. }) {
                                continue;
                            }
                            match kv.prepare_write(slot).map_err(|e| e.to_string())? {
                                PrepareWrite::Ready | PrepareWrite::Forked => {
                                    let pos = kv.position(slot).expect("active slot");
                                    let page = kv.blocks(slot)[pos / PT];
                                    ensure_eq(
                                        kv.page_refs(page),
                                        1,
                                        "write cursor sits on an exclusively owned page",
                                    )?;
                                    let _ = kv.try_advance(slot).map_err(|e| e.to_string())?;
                                }
                                PrepareWrite::PoolExhausted => {
                                    // Fork starved: preempt this slot — its
                                    // shared pages must drop refs, not free.
                                    let pos = kv.position(slot).expect("active slot");
                                    checked_release(&mut kv, slot)?;
                                    parked.push(pos + 1);
                                }
                            }
                        }
                    }
                    2 => {
                        // Retire the first occupied slot.
                        if let Some(slot) = (0..kv.bucket())
                            .find(|&s| !matches!(kv.state(s), SlotState::Free))
                        {
                            kv.finish(slot).map_err(|e| e.to_string())?;
                            checked_release(&mut kv, slot)?;
                        }
                    }
                    3 => {
                        // Preempt the last active slot (park its replay).
                        if let Some(slot) = (0..kv.bucket())
                            .rev()
                            .find(|&s| matches!(kv.state(s), SlotState::Active { .. }))
                        {
                            let pos = kv.position(slot).expect("active slot");
                            checked_release(&mut kv, slot)?;
                            parked.push(pos + 1);
                        }
                    }
                    4 => {
                        // Restore the parked head through the non-shared
                        // replay path (replayed pages mix prompt and
                        // generated tokens — the index must never serve
                        // them).
                        if let Some(&replay) = parked.first() {
                            if kv.can_restore(replay, 1) {
                                parked.remove(0);
                                kv.allocate(replay).map_err(|e| e.to_string())?;
                            }
                        }
                    }
                    _ => {
                        // Resize to a shape that still fits the occupants.
                        let occ = kv.occupied_count().max(1);
                        kv.resize(occ + i % 4).map_err(|e| e.to_string())?;
                    }
                }
                verify(&kv)?;
            }
            // Drain: every unique page returns to the free list and the
            // alloc/release ledger balances (retains are ref bumps, not
            // allocations — they must not leak pages).
            kv.reset();
            ensure_eq(kv.pool_stats().used_pages, 0, "drained pool is empty")?;
            let stats = kv.pool_stats();
            ensure_eq(stats.allocs, stats.releases, "alloc/release ledger balances")?;
            total_retains.set(total_retains.get() + stats.retains);
            total_forks.set(total_forks.get() + stats.cow_forks);
            verify(&kv)
        },
    );
    assert!(
        total_retains.get() > 0,
        "the generator never shared a page: the property was vacuous"
    );
    assert!(
        total_forks.get() > 0,
        "the churn never forced a CoW fork: the property was vacuous"
    );
}

/// Full-scheduler identity: on an ample budget, a sharing-enabled session
/// produces byte-identical responses (tokens, truncation) to the plain
/// paged pool over the same workload — sharing changes the HBM footprint,
/// never the bytes. The sharing run drives a page-aware mock whose
/// contract rejects any advancing write into a multi-mapped page, so a
/// clean run additionally proves no write-through ever reached the
/// backend.
#[test]
fn prop_shared_prefix_scheduler_byte_identical() {
    let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
    let total_hits = std::cell::Cell::new(0usize);
    let run = |share: bool,
               bucket: usize,
               shapes: &[(u8, u8)]|
     -> Result<(Vec<(u64, Vec<u32>, bool)>, usize, usize), String> {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
        let mut be = MockBackend::new(64, 48, 96, script);
        let mut kv = KvConfig::paged(16, 4096);
        if share {
            kv = kv.with_prefix_sharing();
            be = be.with_page_tokens(16);
        }
        let sched =
            Scheduler::new(&tk, SchedulerConfig::fixed(bucket, AdmitGate::Continuous).with_kv(kv));
        let requests: Vec<Request> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(tag, examples))| {
                let ex: Vec<(Vec<u8>, Vec<u8>)> = (0..examples)
                    .map(|_| (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]))
                    .collect();
                Request::new(i as u64, "7b-sim", "int8", modes[tag as usize], ex)
            })
            .collect();
        let (resps, report) = sched.run_batch(&mut be, &requests).map_err(|e| e.to_string())?;
        ensure_eq(
            report.kv_pages_allocated,
            report.kv_pages_released,
            "page ledger balances under sharing",
        )?;
        Ok((
            resps.into_iter().map(|r| (r.id, r.tokens, r.truncated)).collect(),
            report.deferred,
            report.kv_prefix_hits,
        ))
    };
    check(
        "shared-prefix-byte-identical",
        25,
        0xC0B1,
        |rng| {
            let bucket = rng.range(2, 6);
            // Shapes drawn from a small alphabet so duplicate prompts (and
            // therefore shared prefixes) actually occur.
            let shapes: Vec<(u8, u8)> = (0..rng.range(2, 8))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8))
                .collect();
            (bucket, shapes)
        },
        |(bucket, shapes)| {
            let (plain, plain_deferred, plain_hits) = run(false, *bucket, shapes)?;
            let (shared, shared_deferred, hits) = run(true, *bucket, shapes)?;
            ensure_eq(plain_hits, 0, "sharing off records no prefix hits")?;
            ensure_eq(plain_deferred, 0, "ample plain pool never defers")?;
            ensure_eq(shared_deferred, 0, "ample shared pool never defers")?;
            ensure(shared == plain, "shared-prefix run diverged from the plain paged run")?;
            total_hits.set(total_hits.get() + hits);
            Ok(())
        },
    );
    assert!(
        total_hits.get() > 0,
        "the generator never shared a prefix: the property was vacuous"
    );
}

// ---------------------------------------------------------------------------
// Admission policy
// ---------------------------------------------------------------------------

fn mk_request(id: u64, mode: CotMode) -> Request {
    Request::new(id, "7b-sim", "int8", mode, vec![])
}

#[test]
fn prop_admission_conserves_requests_and_orders_within_mode() {
    check_vec(
        "admission-conservation",
        60,
        0xC33,
        |rng| {
            let n = rng.range(1, 40);
            (0..n)
                .map(|_| rng.range(0, 2) as u8) // inclusive: tags 0..=2
                .collect::<Vec<u8>>()
        },
        |mode_tags| {
            let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
            let mut q = AdmissionQueue::new(AdmitConfig::with_wait(
                true,
                std::time::Duration::from_secs(3600),
            ));
            for (id, &tag) in mode_tags.iter().enumerate() {
                q.push(mk_request(id as u64, modes[tag as usize]));
            }
            let now = std::time::Instant::now();
            let mut drained: Vec<(u8, u64)> = Vec::new();
            while let Some(r) = q.admit(now) {
                let tag = modes.iter().position(|&m| m == r.mode).unwrap() as u8;
                drained.push((tag, r.id));
            }
            ensure_eq(drained.len(), mode_tags.len(), "all requests admitted exactly once")?;
            let mut ids: Vec<u64> = drained.iter().map(|&(_, id)| id).collect();
            ids.sort_unstable();
            ensure(
                ids == (0..mode_tags.len() as u64).collect::<Vec<_>>(),
                "no request lost or duplicated",
            )?;
            // Within one mode, admission preserves arrival order (FIFO).
            for tag in 0..3u8 {
                let per_mode: Vec<u64> = drained
                    .iter()
                    .filter(|&&(t, _)| t == tag)
                    .map(|&(_, id)| id)
                    .collect();
                ensure(
                    per_mode.windows(2).all(|w| w[0] < w[1]),
                    format!("FIFO broken within mode {tag}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_admission_fifo_when_mode_blind() {
    check_vec(
        "admission-fifo",
        40,
        0xC44,
        |rng| {
            let n = rng.range(1, 40);
            (0..n)
                .map(|_| rng.range(0, 2) as u8) // inclusive: tags 0..=2
                .collect::<Vec<u8>>()
        },
        |mode_tags| {
            let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
            let mut q = AdmissionQueue::new(AdmitConfig::with_wait(
                false,
                std::time::Duration::ZERO,
            ));
            for (id, &tag) in mode_tags.iter().enumerate() {
                q.push(mk_request(id as u64, modes[tag as usize]));
            }
            let now = std::time::Instant::now();
            let mut drained = Vec::new();
            while let Some(r) = q.admit(now) {
                drained.push(r.id);
            }
            ensure(
                drained.windows(2).all(|w| w[0] < w[1]),
                "mode-blind admission must be strict FIFO",
            )
        },
    );
}

#[test]
fn prop_kv_release_recycles_slots() {
    check(
        "kv-release-recycle",
        80,
        0xC55,
        |rng| {
            let bucket = rng.range(1, 12);
            let released = rng.range(0, bucket); // inclusive: 0..=bucket
            (bucket, released)
        },
        |&(bucket, released)| {
            let mut kv = KvSlots::new(bucket, 96);
            for _ in 0..bucket {
                kv.allocate(10).map_err(|e| e.to_string())?;
            }
            ensure(kv.allocate(10).is_err(), "full bucket must reject")?;
            for slot in 0..released {
                kv.finish(slot).map_err(|e| e.to_string())?;
                kv.release(slot).map_err(|e| e.to_string())?;
            }
            ensure_eq(kv.free_count(), released, "released slots are free")?;
            ensure_eq(kv.occupied_count(), bucket - released, "rest stay occupied")?;
            // Every released slot is re-allocatable at a fresh position.
            for i in 0..released {
                let slot = kv.allocate(20 + i).map_err(|e| e.to_string())?;
                ensure(slot < bucket, "slot out of range")?;
                ensure_eq(
                    kv.state(slot),
                    SlotState::Active { pos: 20 + i },
                    "fresh position",
                )?;
            }
            ensure(kv.allocate(10).is_err(), "bucket full again")?;
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// SLO decision table: feasible-or-cheapest, deterministic, totally ordered
// ---------------------------------------------------------------------------

/// Fuzz of [`SloPolicy::decide`] against a reference oracle over the full
/// candidate table, across random budgets, queue depths, pool headroom,
/// arrival pairs, and inflation factors: the decision is always the FIRST
/// feasible candidate in degradation order, or (when nothing is feasible)
/// the globally cheapest one — earliest rank on ties — flagged as a miss;
/// identical snapshots always decide identically; every candidate cost is
/// finite, so the cost comparison is a genuine (antisymmetric) total order;
/// and the downgrade flags exactly reflect pair-vs-arrival inequality.
#[test]
fn prop_slo_decision_feasible_or_cheapest_and_deterministic() {
    check(
        "slo-decision-table",
        120,
        0x510D,
        |rng| {
            let prompt = rng.range(1, 64);
            let queued = [rng.range(0, 6), rng.range(0, 6), rng.range(0, 6)];
            let headroom = if rng.chance(0.5) {
                let capacity = rng.range(2, 24);
                Some((capacity, rng.range(0, capacity)))
            } else {
                None
            };
            let horizon = rng.range(1, 32);
            let ap = rng.range(0, 4); // inclusive: every Precision
            let am = rng.range(0, 2); // inclusive: every CotMode
            let budget_c = rng.range(0, 1_000_000); // centi-ms: 0..=10s
            let i8x = 100 + rng.range(0, 40);
            let w4x = 100 + rng.range(0, 60);
            let allow_mode = rng.chance(0.8);
            (prompt, queued, headroom, horizon, ap, am, budget_c, i8x, w4x, allow_mode)
        },
        |&(prompt, queued, headroom, horizon, ap, am, budget_c, i8x, w4x, allow_mode)| {
            let cost = AtlasCostModel::openpangu_7b().with_token_inflation(TokenInflation {
                int8: i8x as f64 / 100.0,
                w4a8: w4x as f64 / 100.0,
            });
            let policy = SloPolicy { allow_mode_downgrade: allow_mode, ..SloPolicy::default() };
            let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
            let arrival = (Precision::ALL[ap], modes[am]);
            let snap = SloSnapshot {
                prompt_tokens: prompt,
                queued_by_mode: queued,
                headroom: headroom.map(|(capacity, free)| PoolHeadroom {
                    page_tokens: 16,
                    used_pages: capacity - free,
                    free_pages: free,
                    capacity_pages: capacity,
                }),
                grow_horizon: horizon,
            };
            let slo_ms = budget_c as f64 / 100.0;
            let d = policy.decide(&cost, arrival, slo_ms, &snap);
            ensure(
                policy.decide(&cost, arrival, slo_ms, &snap) == d,
                "identical snapshots decided differently",
            )?;
            let wait = SloPolicy::queue_wait_ms(&cost, arrival.0, &snap);
            let cands = policy.candidates(arrival);
            ensure(cands[0] == arrival, "rank 0 must be the arrival pair")?;
            let costs: Vec<f64> = cands
                .iter()
                .map(|&(p, m)| wait + SloPolicy::service_ms(&cost, p, m, &snap))
                .collect();
            for &c in &costs {
                ensure(c.is_finite(), format!("candidate cost must be finite, got {c}"))?;
            }
            let feasible: Vec<bool> = cands
                .iter()
                .zip(&costs)
                .map(|(&(p, m), &ms)| ms <= slo_ms && SloPolicy::pool_fits(&cost, p, m, &snap))
                .collect();
            if let Some(first) = feasible.iter().position(|&f| f) {
                ensure(!d.modeled_miss, "a feasible candidate existed but the decision missed")?;
                ensure_eq(d.rank, first, "decide must take the FIRST feasible rank")?;
                ensure_eq((d.precision, d.mode), cands[first], "pair matches the chosen rank")?;
                ensure_eq(d.modeled_ms, costs[first], "modeled ms matches the table")?;
            } else {
                ensure(d.modeled_miss, "no candidate was feasible but no miss was flagged")?;
                let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
                let argmin = costs.iter().position(|&c| c == min).unwrap();
                ensure_eq(d.rank, argmin, "a miss takes the cheapest candidate, first on ties")?;
                ensure_eq(d.modeled_ms, min, "miss modeled ms is the table minimum")?;
            }
            ensure_eq(d.downgraded_mode, d.mode != arrival.1, "mode flag consistent")?;
            ensure_eq(d.downgraded_precision, d.precision != arrival.0, "precision flag")
        },
    );
}

// ---------------------------------------------------------------------------
// Quantization round trips (Rust mirror, arbitrary values)
// ---------------------------------------------------------------------------

#[test]
fn prop_int4_pack_roundtrip() {
    check(
        "int4-pack-roundtrip",
        100,
        0xD44,
        |rng| {
            let k = 2 * rng.range(1, 64);
            let n = rng.range(1, 16);
            let vals: Vec<i8> = (0..k * n).map(|_| rng.range(0, 15) as i8 - 8).collect();
            (k, n, vals)
        },
        |(k, n, vals)| {
            let packed = int4::pack(vals, *k, *n);
            ensure_eq(packed.len(), k / 2 * n, "packed size")?;
            let back = int4::unpack(&packed, k / 2, *n);
            ensure(back == *vals, "unpack != original")
        },
    );
}

#[test]
fn prop_int8_quant_error_bound() {
    check(
        "int8-error-bound",
        60,
        0xE55,
        |rng| {
            let k = rng.range(2, 32);
            let n = rng.range(1, 8);
            let scale = 10f32.powi(rng.range(0, 6) as i32 - 3);
            let vals: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * scale).collect();
            (k, n, vals)
        },
        |(k, n, vals)| {
            let (q, s) = int8::quant_weight_per_channel(vals, *k, *n);
            for row in 0..*k {
                for col in 0..*n {
                    let deq = q[row * n + col] as f32 * s[col];
                    let err = (deq - vals[row * n + col]).abs();
                    ensure(
                        err <= s[col] / 2.0 + 1e-6,
                        format!("error {err} > half-scale {}", s[col] / 2.0),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_int4_quant_error_bound() {
    check(
        "int4-error-bound",
        60,
        0xE66,
        |rng| {
            let k = rng.range(1, 32);
            let n = rng.range(1, 8);
            let scale = 10f32.powi(rng.range(0, 6) as i32 - 3);
            let vals: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * scale).collect();
            (k, n, vals)
        },
        |(k, n, vals)| {
            let (q, s) = int4::quant_weight_per_channel(vals, *k, *n);
            for col in 0..*n {
                ensure(s[col] > 0.0, "scale must stay positive")?;
            }
            for row in 0..*k {
                for col in 0..*n {
                    ensure(
                        (-7..=7).contains(&q[row * n + col]),
                        format!("q out of int4 range: {}", q[row * n + col]),
                    )?;
                    let deq = q[row * n + col] as f32 * s[col];
                    let err = (deq - vals[row * n + col]).abs();
                    ensure(
                        err <= s[col] / 2.0 + 1e-6,
                        format!("error {err} > half-scale {}", s[col] / 2.0),
                    )?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Fleet: multi-device conservation and single-device identity
// ---------------------------------------------------------------------------

/// Randomized workloads over randomized fleets (1–4 devices, both router
/// policies, tight per-device paged pools with preempt-and-recompute on):
/// every request is answered exactly once by exactly one device, in input
/// order, with tokens; placement accounting is conserved through
/// rebalance; and the fleet-wide page ledger balances.
///
/// Pool sizing mirrors the preempt conservation suite: every sequence
/// peaks at <= 4 pages (28-token prompt + 30-token trace), so 5..=8 pages
/// per device starve often but can always restore — distress is reachable
/// (exercising the rebalance path) without truncation being forced.
#[test]
fn prop_fleet_conserves_requests() {
    use pangu_atlas_quant::coordinator::fleet::{
        Fleet, FleetConfig, LeastLoadedRouter, RoundRobinRouter, RouterPolicy,
    };
    use pangu_atlas_quant::runtime::backend::MockProvider;
    let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
    check(
        "fleet-conservation",
        25,
        0xF1EE7,
        |rng| {
            let devices = rng.range(1, 4);
            let shapes: Vec<(u8, u8)> = (0..rng.range(2, 10))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8))
                .collect();
            let pages = rng.range(5, 8);
            let cost_router = rng.chance(0.5);
            (devices, shapes, pages, cost_router)
        },
        |(devices, shapes, pages, cost_router)| {
            let tk = Tokenizer::minilang_default();
            let sched_cfg = SchedulerConfig::fixed(2, AdmitGate::Continuous)
                .with_kv(KvConfig::paged(16, pages * 16))
                .with_preempt(PreemptConfig::enabled());
            let cfg = FleetConfig::homogeneous(
                *devices,
                sched_cfg,
                AdmitConfig::with_wait(false, Duration::ZERO),
            );
            let policy: Box<dyn RouterPolicy> = if *cost_router {
                Box::new(LeastLoadedRouter::new())
            } else {
                Box::new(RoundRobinRouter::new())
            };
            let mut fleet = Fleet::new(&tk, cfg, policy).map_err(|e| e.to_string())?;
            let mut providers: Vec<_> = (0..*devices)
                .map(|_| {
                    let script =
                        pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
                    MockProvider::new(MockBackend::new(64, 48, 96, script))
                })
                .collect();
            let requests: Vec<Request> = shapes
                .iter()
                .enumerate()
                .map(|(i, &(tag, examples))| {
                    let ex: Vec<(Vec<u8>, Vec<u8>)> = (0..examples)
                        .map(|_| (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]))
                        .collect();
                    Request::new(i as u64, "7b-sim", "int8", modes[tag as usize], ex)
                })
                .collect();
            let (resps, report) =
                fleet.run_batch(&mut providers, &requests).map_err(|e| e.to_string())?;
            ensure_eq(resps.len(), requests.len(), "every request answered exactly once")?;
            for (i, r) in resps.iter().enumerate() {
                ensure_eq(r.id, i as u64, "responses in input order, no loss/duplication")?;
                ensure(!r.tokens.is_empty(), format!("request {i} got tokens"))?;
            }
            ensure_eq(
                report.placements(),
                requests.len(),
                "placement accounting conserved through rebalance",
            )?;
            let total = report.rollup();
            ensure_eq(total.completed, requests.len(), "rollup completion agrees")?;
            ensure_eq(
                total.kv_pages_allocated,
                total.kv_pages_released,
                "fleet-wide page conservation",
            )?;
            Ok(())
        },
    );
}

/// A single-device fleet is the bare scheduler: same responses
/// byte-for-byte (tokens, truncation, first-token step) and the same
/// schedule accounting. The fleet layer must add routing, not behavior.
#[test]
fn prop_single_device_fleet_matches_bare_scheduler() {
    use pangu_atlas_quant::coordinator::fleet::{Fleet, FleetConfig, LeastLoadedRouter};
    use pangu_atlas_quant::runtime::backend::MockProvider;
    let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
    check(
        "fleet-single-device-identity",
        25,
        0xF1D1,
        |rng| {
            let bucket = rng.range(1, 6);
            let shapes: Vec<(u8, u8)> = (0..rng.range(1, 8))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8))
                .collect();
            (bucket, shapes)
        },
        |(bucket, shapes)| {
            let tk = Tokenizer::minilang_default();
            let requests: Vec<Request> = shapes
                .iter()
                .enumerate()
                .map(|(i, &(tag, examples))| {
                    let ex: Vec<(Vec<u8>, Vec<u8>)> = (0..examples)
                        .map(|_| (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]))
                        .collect();
                    Request::new(i as u64, "7b-sim", "int8", modes[tag as usize], ex)
                })
                .collect();
            let sched_cfg = SchedulerConfig::fixed(*bucket, AdmitGate::Continuous);

            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
            let mut be = MockBackend::new(64, 48, 96, script);
            let (bare_resps, bare_report) = Scheduler::new(&tk, sched_cfg.clone())
                .run_batch(&mut be, &requests)
                .map_err(|e| e.to_string())?;

            let cfg = FleetConfig::homogeneous(
                1,
                sched_cfg,
                AdmitConfig::with_wait(false, Duration::ZERO),
            );
            let mut fleet = Fleet::new(&tk, cfg, Box::new(LeastLoadedRouter::new()))
                .map_err(|e| e.to_string())?;
            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
            let mut providers = vec![MockProvider::new(MockBackend::new(64, 48, 96, script))];
            let (fleet_resps, fleet_report) =
                fleet.run_batch(&mut providers, &requests).map_err(|e| e.to_string())?;

            ensure_eq(fleet_resps.len(), bare_resps.len(), "same response count")?;
            for (a, b) in bare_resps.iter().zip(&fleet_resps) {
                ensure_eq(a.id, b.id, "same response order")?;
                ensure(a.tokens == b.tokens, format!("request {} tokens diverged", a.id))?;
                ensure_eq(a.truncated, b.truncated, "same truncation")?;
                ensure_eq(a.first_token_step, b.first_token_step, "same schedule")?;
            }
            let total = fleet_report.rollup();
            ensure_eq(total.decode_steps, bare_report.decode_steps, "same decode steps")?;
            ensure_eq(total.slot_steps(), bare_report.slot_steps(), "same slot-steps")?;
            ensure_eq(total.completed, bare_report.completed, "same completions")?;
            ensure_eq(total.admitted, bare_report.admitted, "same admissions")?;
            ensure_eq(total.joins, bare_report.joins, "same joins")?;
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// MiniLang VM totality: any program over any input halts in domain.
// ---------------------------------------------------------------------------

#[test]
fn prop_vm_total_and_closed() {
    check(
        "vm-total",
        200,
        0xF66,
        |rng| {
            let ops: Vec<Op> = (0..rng.range(0, 8))
                .map(|_| Op::ALL[rng.range(0, Op::ALL.len() - 1)])
                .collect();
            let input: Vec<u8> = (0..rng.range(1, 12)).map(|_| rng.range(0, 15) as u8).collect();
            (ops, input)
        },
        |(ops, input)| {
            let out = Program(ops.clone())
                .run(input, 16)
                .map_err(|e| e.to_string())?;
            ensure_eq(out.len(), input.len(), "length preserved")?;
            ensure(out.iter().all(|&v| v < 16), "value escaped domain")
        },
    );
}

// ---------------------------------------------------------------------------
// Sampler: always returns a valid token id; greedy matches max.
// ---------------------------------------------------------------------------

#[test]
fn prop_sampler_in_range() {
    use pangu_atlas_quant::coordinator::sampling;
    use pangu_atlas_quant::util::prng::Rng;
    check(
        "sampler-range",
        100,
        0xAB7,
        |rng| {
            let v = rng.range(2, 64);
            let logits: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * 3.0).collect();
            let temp = rng.f32() * 2.0;
            let top_k = rng.range(0, v);
            (logits, temp, top_k, rng.next_u64())
        },
        |(logits, temp, top_k, seed)| {
            let mut r = Rng::new(*seed);
            let t = sampling::sample(logits, *temp, *top_k, &mut r);
            ensure((t as usize) < logits.len(), "token out of vocab")?;
            if *temp == 0.0 {
                ensure_eq(t, sampling::greedy(logits), "greedy mismatch")?;
            }
            Ok(())
        },
    );
}
