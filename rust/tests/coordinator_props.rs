//! Property tests (propcheck) over coordinator invariants: routing,
//! batching, KV state management, packing round-trips, VM totality.

use pangu_atlas_quant::bench_suite::vm::{Op, Program};
use pangu_atlas_quant::coordinator::batcher::{Batcher, BatcherConfig};
use pangu_atlas_quant::coordinator::kv::{KvSlots, SlotState};
use pangu_atlas_quant::coordinator::request::Request;
use pangu_atlas_quant::quant::{int4, int8};
use pangu_atlas_quant::tokenizer::CotMode;
use pangu_atlas_quant::util::propcheck::{check, check_vec, ensure, ensure_eq};

// ---------------------------------------------------------------------------
// KV slots
// ---------------------------------------------------------------------------

#[test]
fn prop_kv_slots_never_double_allocate() {
    check(
        "kv-unique-slots",
        100,
        0xA11,
        |rng| {
            let bucket = rng.range(1, 16);
            let n_alloc = rng.range(1, bucket);
            (bucket, n_alloc)
        },
        |&(bucket, n_alloc)| {
            let mut kv = KvSlots::new(bucket, 96);
            let mut seen = std::collections::HashSet::new();
            for _ in 0..n_alloc {
                let slot = kv.allocate(10).map_err(|e| e.to_string())?;
                ensure(seen.insert(slot), format!("slot {slot} allocated twice"))?;
                ensure(slot < bucket, "slot out of range")?;
            }
            ensure_eq(kv.active_count(), n_alloc, "active count")
        },
    );
}

#[test]
fn prop_kv_positions_bounded_by_window() {
    check(
        "kv-window-bound",
        100,
        0xB22,
        |rng| {
            let max_seq = rng.range(8, 64);
            let prompt = rng.range(1, max_seq - 1);
            let steps = rng.range(0, 2 * max_seq);
            (max_seq, prompt, steps)
        },
        |&(max_seq, prompt, steps)| {
            let mut kv = KvSlots::new(1, max_seq);
            let s = kv.allocate(prompt).map_err(|e| e.to_string())?;
            for _ in 0..steps {
                match kv.state(s) {
                    SlotState::Active { pos } => {
                        ensure(pos < max_seq, format!("pos {pos} >= window {max_seq}"))?;
                        let _ = kv.advance(s).map_err(|e| e.to_string())?;
                    }
                    SlotState::Finished { pos } => {
                        ensure(pos < max_seq, "finished past window")?;
                        break;
                    }
                    SlotState::Free => return Err("slot freed mid-run".into()),
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Batcher
// ---------------------------------------------------------------------------

fn mk_request(id: u64) -> Request {
    Request::new(id, "7b-sim", "int8", CotMode::NoThink, vec![])
}

#[test]
fn prop_batcher_preserves_fifo_and_never_overflows() {
    check_vec(
        "batcher-fifo",
        60,
        0xC33,
        |rng| {
            let n = rng.range(1, 40);
            (0..n as u64).collect::<Vec<u64>>()
        },
        |ids| {
            let mut b = Batcher::new(BatcherConfig {
                buckets: vec![1, 4, 8],
                max_wait: std::time::Duration::from_millis(0),
            });
            for &id in ids {
                b.push(mk_request(id));
            }
            let mut drained = Vec::new();
            while let Some(w) = b.flush() {
                ensure(w.requests.len() <= w.bucket, "wave overflows bucket")?;
                ensure(
                    [1usize, 4, 8].contains(&w.bucket),
                    format!("unknown bucket {}", w.bucket),
                )?;
                drained.extend(w.requests.iter().map(|r| r.id));
            }
            ensure_eq(drained.len(), ids.len(), "all requests drained")?;
            ensure(drained.windows(2).all(|w| w[0] < w[1]), "FIFO order broken")
        },
    );
}

// ---------------------------------------------------------------------------
// Quantization round trips (Rust mirror, arbitrary values)
// ---------------------------------------------------------------------------

#[test]
fn prop_int4_pack_roundtrip() {
    check(
        "int4-pack-roundtrip",
        100,
        0xD44,
        |rng| {
            let k = 2 * rng.range(1, 64);
            let n = rng.range(1, 16);
            let vals: Vec<i8> = (0..k * n).map(|_| rng.range(0, 15) as i8 - 8).collect();
            (k, n, vals)
        },
        |(k, n, vals)| {
            let packed = int4::pack(vals, *k, *n);
            ensure_eq(packed.len(), k / 2 * n, "packed size")?;
            let back = int4::unpack(&packed, k / 2, *n);
            ensure(back == *vals, "unpack != original")
        },
    );
}

#[test]
fn prop_int8_quant_error_bound() {
    check(
        "int8-error-bound",
        60,
        0xE55,
        |rng| {
            let k = rng.range(2, 32);
            let n = rng.range(1, 8);
            let scale = 10f32.powi(rng.range(0, 6) as i32 - 3);
            let vals: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * scale).collect();
            (k, n, vals)
        },
        |(k, n, vals)| {
            let (q, s) = int8::quant_weight_per_channel(vals, *k, *n);
            for row in 0..*k {
                for col in 0..*n {
                    let deq = q[row * n + col] as f32 * s[col];
                    let err = (deq - vals[row * n + col]).abs();
                    ensure(
                        err <= s[col] / 2.0 + 1e-6,
                        format!("error {err} > half-scale {}", s[col] / 2.0),
                    )?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// MiniLang VM totality: any program over any input halts in domain.
// ---------------------------------------------------------------------------

#[test]
fn prop_vm_total_and_closed() {
    check(
        "vm-total",
        200,
        0xF66,
        |rng| {
            let ops: Vec<Op> = (0..rng.range(0, 8))
                .map(|_| Op::ALL[rng.range(0, Op::ALL.len() - 1)])
                .collect();
            let input: Vec<u8> = (0..rng.range(1, 12)).map(|_| rng.range(0, 15) as u8).collect();
            (ops, input)
        },
        |(ops, input)| {
            let out = Program(ops.clone())
                .run(input, 16)
                .map_err(|e| e.to_string())?;
            ensure_eq(out.len(), input.len(), "length preserved")?;
            ensure(out.iter().all(|&v| v < 16), "value escaped domain")
        },
    );
}

// ---------------------------------------------------------------------------
// Sampler: always returns a valid token id; greedy matches max.
// ---------------------------------------------------------------------------

#[test]
fn prop_sampler_in_range() {
    use pangu_atlas_quant::coordinator::sampling;
    use pangu_atlas_quant::util::prng::Rng;
    check(
        "sampler-range",
        100,
        0xAB7,
        |rng| {
            let v = rng.range(2, 64);
            let logits: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * 3.0).collect();
            let temp = rng.f32() * 2.0;
            let top_k = rng.range(0, v);
            (logits, temp, top_k, rng.next_u64())
        },
        |(logits, temp, top_k, seed)| {
            let mut r = Rng::new(*seed);
            let t = sampling::sample(logits, *temp, *top_k, &mut r);
            ensure((t as usize) < logits.len(), "token out of vocab")?;
            if *temp == 0.0 {
                ensure_eq(t, sampling::greedy(logits), "greedy mismatch")?;
            }
            Ok(())
        },
    );
}
