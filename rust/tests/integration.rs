//! Integration tests over the real artifacts: runtime loading, numerics
//! consistency (prefill/decode vs the Python oracle's expectations),
//! cross-language dataset validation, variant divergence ordering.
//!
//! Skips cleanly (prints + passes) when artifacts have not been built —
//! `make artifacts` first.

use std::path::PathBuf;

use anyhow::Result;
use pangu_atlas_quant::bench_suite::dataset::Benchmark;
use pangu_atlas_quant::harness::Harness;
use pangu_atlas_quant::runtime::Runtime;
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn manifest_and_weights_load() -> Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let mut rt = Runtime::open(&dir)?;
    assert!(rt.manifest.models.contains_key("1b-sim"));
    assert!(rt.manifest.models.contains_key("7b-sim"));
    assert!(rt.manifest.executables.len() >= 30);
    // Upload a bundle and verify tensor count matches the manifest listing.
    rt.ensure_weights("7b-sim_int8")?;
    Ok(())
}

#[test]
fn datasets_cross_validate_against_vm() -> Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let rt = Runtime::open(&dir)?;
    for (name, rel) in rt.manifest.datasets.clone() {
        let b = Benchmark::load(&dir.join(rel))?;
        // Every (example, test) pair in the Python-generated dataset must
        // replay exactly on the Rust VM — the cross-language golden check.
        b.validate()?;
        let expected = if name == "humaneval_s" { 164 } else { 257 };
        assert_eq!(b.tasks.len(), expected, "{name} task count");
    }
    Ok(())
}

#[test]
fn prefill_then_decode_emits_sane_tokens() -> Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let mut rt = Runtime::open(&dir)?;
    let tk = Tokenizer::from_manifest(&rt.manifest.raw)?;
    let b = Benchmark::load(&dir.join(&rt.manifest.datasets["humaneval_s"]))?;
    let prompt = tk.encode_prompt(CotMode::NoThink, &b.tasks[0].examples);
    let plen = rt.manifest.prompt_len;
    let mut tokens = vec![tk.pad as i32; plen];
    for (i, &t) in prompt.iter().enumerate() {
        tokens[i] = t as i32;
    }
    let state = rt.prefill("7b-sim", "int8", 1, &tokens, &[prompt.len() as i32])?;
    let logits = rt.readout("7b-sim", &state)?;
    assert_eq!(logits.len(), 64);
    assert!(logits.iter().all(|v| v.is_finite()), "non-finite logits");
    // Greedy next token should be a structural token (PROG or TRACE family),
    // not PAD — the trained model always opens a completion.
    let arg = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as u32;
    assert_ne!(arg, tk.pad, "model emits PAD as first token");

    // One decode step keeps the state usable and logits finite.
    let state = rt.decode("7b-sim", "int8", state, &[arg as i32], &[prompt.len() as i32])?;
    let logits2 = rt.readout("7b-sim", &state)?;
    assert!(logits2.iter().all(|v| v.is_finite()));
    Ok(())
}

#[test]
fn variant_logits_diverge_in_order() -> Result<()> {
    // ||logits_int8 - logits_fp16|| < ||logits_w4a8 - logits_fp16||:
    // the Table 2 mechanism, measured end-to-end through the runtime.
    let Some(dir) = artifacts() else { return Ok(()) };
    let mut rt = Runtime::open(&dir)?;
    let tk = Tokenizer::from_manifest(&rt.manifest.raw)?;
    let b = Benchmark::load(&dir.join(&rt.manifest.datasets["humaneval_s"]))?;
    let prompt = tk.encode_prompt(CotMode::NoThink, &b.tasks[1].examples);
    let plen = rt.manifest.prompt_len;
    let mut tokens = vec![tk.pad as i32; plen];
    for (i, &t) in prompt.iter().enumerate() {
        tokens[i] = t as i32;
    }
    let lens = [prompt.len() as i32];
    let mut get = |variant: &str| -> Result<Vec<f32>> {
        let st = rt.prefill("7b-sim", variant, 1, &tokens, &lens)?;
        rt.readout("7b-sim", &st)
    };
    let fp = get("fp16")?;
    let i8l = get("int8")?;
    let w4 = get("w4a8")?;
    let dist = |a: &[f32], b: &[f32]| -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    let d8 = dist(&i8l, &fp);
    let d4 = dist(&w4, &fp);
    assert!(d8 < d4, "int8 divergence {d8} !< w4a8 divergence {d4}");
    assert!(d8 < 1.0, "int8 logits far from fp16: {d8}");
    Ok(())
}

#[test]
fn batch_rows_are_independent() -> Result<()> {
    // Same prompt in slot 0 of a b=8 batch and alone at b=1 must produce
    // identical greedy tokens — padding slots must not leak.
    let Some(dir) = artifacts() else { return Ok(()) };
    use pangu_atlas_quant::coordinator::scheduler::{AdmitGate, Scheduler, SchedulerConfig};
    let mut h = Harness::open(&dir)?;
    let task = h.benchmark("humaneval_s")?.tasks[2].clone();
    let tk = h.tokenizer.clone();
    let mk = |id| {
        pangu_atlas_quant::coordinator::request::Request::new(
            id, "7b-sim", "int8", CotMode::NoThink, task.examples.clone(),
        )
    };
    let run_at = |h: &mut Harness, bucket: usize, id: u64| -> Result<Vec<u32>> {
        let scheduler = Scheduler::new(&tk, SchedulerConfig::fixed(bucket, AdmitGate::Continuous));
        let mut backend = pangu_atlas_quant::runtime::backend::DeviceBackend::new(
            &mut h.runtime,
            "7b-sim",
            "int8",
        )?;
        let (resps, _) = scheduler.run_batch(&mut backend, &[mk(id)])?;
        Ok(resps[0].tokens.clone())
    };
    let r1 = run_at(&mut h, 1, 1)?;
    let r8 = run_at(&mut h, 8, 2)?;
    assert_eq!(r1, r8, "batch-1 vs batch-8 generation differs");
    Ok(())
}

#[test]
fn fig1_dump_is_consistent_with_quant_mirror() -> Result<()> {
    // The smoothed activation range in the Fig. 1 dump must never exceed
    // the baseline range (SmoothQuant divides by s >= 1e-2 calibrated on
    // these very activations).
    let Some(dir) = artifacts() else { return Ok(()) };
    let data = pangu_atlas_quant::util::json::Json::parse_file(&dir.join("fig1_channels.json"))?;
    let base = data.get("act_baseline").to_f64_vec().unwrap();
    let smooth = data.get("act_smooth").to_f64_vec().unwrap();
    assert_eq!(base.len(), smooth.len());
    let max_b = base.iter().fold(0f64, |a, &v| a.max(v));
    let max_s = smooth.iter().fold(0f64, |a, &v| a.max(v));
    assert!(max_s <= max_b * 1.01, "smoothing increased the max: {max_s} > {max_b}");
    Ok(())
}
