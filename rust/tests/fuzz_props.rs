//! Fuzz-style randomized property tests over the zero-copy hot paths:
//!
//!   * tokenizer: encode/render round-trips on arbitrary example shapes,
//!     streaming `_into` variants byte-identical to their allocating twins,
//!     rendering total over arbitrary (out-of-vocab) ids;
//!   * util/json: parse <-> serialize round-trips (compact and pretty,
//!     escapes / unicode / nesting), borrowed-slice path identical to the
//!     owned path, parser totality on random byte soup (no panics, errors
//!     carry consistent line/column positions);
//!   * quant: int8 round-trip error bounded by scale/2, int4 pack/unpack
//!     a perfect inverse plus the same round-trip bound.
//!
//! Driven by `util::propcheck`; case counts scale with `PROPCHECK_SCALE`
//! (the props-extended CI job runs these at 8x).

use std::borrow::Cow;
use std::collections::BTreeMap;

use pangu_atlas_quant::quant::{int4, int8};
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};
use pangu_atlas_quant::util::json::{Json, JsonSlice};
use pangu_atlas_quant::util::prng::Rng;
use pangu_atlas_quant::util::propcheck::{check, ensure, ensure_eq};

// ---------------------------------------------------------------- tokenizer

fn gen_examples(rng: &mut Rng) -> Vec<(Vec<u8>, Vec<u8>)> {
    let n = rng.range(0, 4);
    (0..n)
        .map(|_| {
            let xs: Vec<u8> = (0..rng.range(0, 6)).map(|_| rng.below(16) as u8).collect();
            let ys: Vec<u8> = (0..rng.range(0, 6)).map(|_| rng.below(16) as u8).collect();
            (xs, ys)
        })
        .collect()
}

fn gen_mode(rng: &mut Rng) -> CotMode {
    *rng.choose(&[CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink])
}

/// Decode an encoded prompt back to (mode, examples) by walking the layout
/// `BOS MODE (IN xs OUT ys | SEP)* ASK` — the inverse the encoder must admit.
fn decode_prompt(tk: &Tokenizer, ids: &[u32]) -> Option<(u32, Vec<(Vec<u8>, Vec<u8>)>)> {
    if ids.len() < 3 || ids[0] != tk.bos || *ids.last().unwrap() != tk.ask {
        return None;
    }
    let mode = ids[1];
    let mut examples = Vec::new();
    let body = &ids[2..ids.len() - 1];
    let mut i = 0;
    while i < body.len() {
        if !examples.is_empty() {
            if body[i] != tk.sep {
                return None;
            }
            i += 1;
        }
        if body.get(i) != Some(&tk.tok_in) {
            return None;
        }
        i += 1;
        let mut xs = Vec::new();
        while let Some(v) = body.get(i).and_then(|&t| tk.digit_value(t)) {
            xs.push(v);
            i += 1;
        }
        if body.get(i) != Some(&tk.tok_out) {
            return None;
        }
        i += 1;
        let mut ys = Vec::new();
        while let Some(v) = body.get(i).and_then(|&t| tk.digit_value(t)) {
            ys.push(v);
            i += 1;
        }
        examples.push((xs, ys));
    }
    Some((mode, examples))
}

#[test]
fn prop_encode_prompt_roundtrips_and_sizes_exactly() {
    let tk = Tokenizer::minilang_default();
    check(
        "encode-prompt-roundtrip",
        300,
        0xF022_0001,
        |rng| (gen_mode(rng), gen_examples(rng)),
        |(mode, examples)| {
            let ids = tk.encode_prompt(*mode, examples);
            ensure_eq(ids.len(), tk.prompt_len(examples), "prompt_len must be exact")?;
            let (got_mode, got_examples) =
                decode_prompt(&tk, &ids).ok_or("encoded prompt does not match the layout")?;
            ensure_eq(got_mode, tk.mode_token(*mode), "mode token")?;
            ensure_eq(got_examples, examples.clone(), "examples round-trip")
        },
    );
}

#[test]
fn prop_encode_prompt_into_is_identical_to_encode_prompt() {
    let tk = Tokenizer::minilang_default();
    check(
        "encode-prompt-into-identity",
        300,
        0xF022_0002,
        |rng| (gen_mode(rng), gen_examples(rng), rng.range(0, 8)),
        |(mode, examples, prefix)| {
            let fresh = tk.encode_prompt(*mode, examples);
            // Appending into a dirty reused buffer must not disturb the
            // prefix and must append exactly the fresh encoding.
            let mut out: Vec<u32> = vec![u32::MAX; *prefix];
            tk.encode_prompt_into(*mode, examples, &mut out);
            ensure(
                out[..*prefix].iter().all(|&t| t == u32::MAX),
                "prefix clobbered",
            )?;
            ensure_eq(&out[*prefix..], fresh.as_slice(), "appended encoding")
        },
    );
}

#[test]
fn prop_render_is_total_and_matches_the_legacy_join() {
    let tk = Tokenizer::minilang_default();
    check(
        "render-total-legacy-identity",
        300,
        0xF022_0003,
        |rng| {
            let n = rng.range(0, 24);
            (0..n)
                .map(|_| {
                    if rng.chance(0.2) {
                        // Out-of-vocab, including ids near u32::MAX.
                        (rng.next_u64() >> 32) as u32
                    } else {
                        rng.below(tk.vocab_size() as u64) as u32
                    }
                })
                .collect::<Vec<u32>>()
        },
        |ids| {
            // Legacy shape: per-token owned Strings + join. render/_into
            // must be byte-identical to it for any ids, in or out of vocab.
            let legacy = ids
                .iter()
                .map(|&t| tk.name(t).to_string())
                .collect::<Vec<_>>()
                .join(" ");
            ensure_eq(tk.render(ids), legacy.clone(), "render vs legacy join")?;
            let mut streamed = String::from("head ");
            tk.render_into(ids, &mut streamed);
            ensure_eq(streamed, format!("head {legacy}"), "render_into appends")
        },
    );
}

#[test]
fn prop_render_of_known_ids_inverts_through_id_lookup() {
    let tk = Tokenizer::minilang_default();
    check(
        "render-id-inverse",
        300,
        0xF022_0004,
        |rng| {
            (0..rng.range(1, 24))
                .map(|_| rng.below(tk.vocab_size() as u64) as u32)
                .collect::<Vec<u32>>()
        },
        |ids| {
            let text = tk.render(ids);
            let back: Option<Vec<u32>> = text.split(' ').map(|name| tk.id(name)).collect();
            ensure_eq(back, Some(ids.clone()), "split + id() recovers the ids")
        },
    );
}

// --------------------------------------------------------------------- json

/// Random strings biased toward the interesting cases: escapes, control
/// characters, multi-byte unicode (including astral-plane chars that
/// serialize via surrogate pairs), and plain ASCII.
fn gen_string(rng: &mut Rng) -> String {
    let pool: &[char] = &[
        'a', 'b', 'z', '0', ' ', '"', '\\', '/', '\n', '\t', '\r', '\u{0}', '\u{1f}', 'é', 'λ',
        '日', '\u{1F600}', '\u{FFFD}',
    ];
    (0..rng.range(0, 12)).map(|_| *rng.choose(pool)).collect()
}

/// Finite numbers only (JSON has no NaN/inf); mix of exact integers and
/// fractional values — both must survive parse -> serialize -> parse.
fn gen_num(rng: &mut Rng) -> f64 {
    match rng.below(4) {
        0 => rng.range(0, 1_000_000) as f64 - 500_000.0,
        1 => rng.normal() * 1e3,
        2 => rng.f64() * 1e-6,
        _ => rng.normal() * 1e12,
    }
}

fn gen_json(rng: &mut Rng, depth: usize) -> Json {
    let leaf_only = depth == 0;
    match rng.below(if leaf_only { 4 } else { 6 }) {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num(gen_num(rng)),
        3 => Json::Str(gen_string(rng)),
        4 => Json::Arr((0..rng.range(0, 5)).map(|_| gen_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.range(0, 5))
                .map(|_| (gen_string(rng), gen_json(rng, depth - 1)))
                .collect::<BTreeMap<_, _>>(),
        ),
    }
}

#[test]
fn prop_json_roundtrips_compact_and_pretty() {
    check(
        "json-roundtrip",
        300,
        0xF022_0005,
        |rng| gen_json(rng, 4),
        |v| {
            let compact = v.to_string();
            ensure_eq(
                Json::parse(&compact).map_err(|e| e.to_string())?,
                v.clone(),
                "compact round-trip",
            )?;
            let pretty = v.to_string_pretty();
            ensure_eq(
                Json::parse(&pretty).map_err(|e| e.to_string())?,
                v.clone(),
                "pretty round-trip",
            )?;
            // Serialization is a function of the value alone: re-serializing
            // the reparsed tree reproduces the bytes.
            ensure_eq(
                Json::parse(&compact).unwrap().to_string(),
                compact,
                "serialize is idempotent",
            )
        },
    );
}

#[test]
fn prop_slice_path_is_identical_to_owned_path() {
    check(
        "json-slice-owned-identity",
        300,
        0xF022_0006,
        |rng| gen_json(rng, 4).to_string(),
        |text| {
            let owned = Json::parse(text).map_err(|e| e.to_string())?;
            let slice = JsonSlice::parse(text).map_err(|e| e.to_string())?;
            ensure_eq(slice.to_owned(), owned.clone(), "slice.to_owned == owned parse")?;
            // Accessors agree too (spot-check strings: lazily-unescaped
            // Cow must equal the eagerly-unescaped owned String).
            if let (Some(a), Some(b)) = (slice.as_str(), owned.as_str()) {
                ensure_eq::<Cow<'_, str>>(a, Cow::Borrowed(b), "as_str")?;
            }
            Ok(())
        },
    );
}

/// Mutate a valid document or emit raw char soup — either way both parsers
/// must terminate without panicking and agree on accept/reject.
fn gen_soup(rng: &mut Rng) -> String {
    let pool: &[char] = &[
        '{', '}', '[', ']', '"', ':', ',', '\\', 'u', 'e', 't', 'f', 'n', '0', '9', '-', '+',
        '.', ' ', '\n', 'é', '\u{1F600}',
    ];
    match rng.below(3) {
        0 => (0..rng.range(0, 40)).map(|_| *rng.choose(pool)).collect(),
        1 => {
            // Structured seed with random single-char edits.
            let mut s: Vec<char> = gen_json(rng, 3).to_string().chars().collect();
            for _ in 0..rng.range(1, 4) {
                if s.is_empty() {
                    break;
                }
                let at = rng.range(0, s.len() - 1);
                if rng.chance(0.5) {
                    s[at] = *rng.choose(pool);
                } else {
                    s.remove(at);
                }
            }
            s.into_iter().collect()
        }
        _ => {
            // Deep nesting: crosses the MAX_DEPTH=128 rejection boundary
            // in both directions without ever overflowing the stack.
            let depth = rng.range(1, 300);
            let open = if rng.chance(0.5) { "[" } else { "{" };
            open.repeat(depth)
        }
    }
}

#[test]
fn prop_parser_is_total_on_byte_soup() {
    check(
        "json-parser-totality",
        400,
        0xF022_0007,
        gen_soup,
        |text| {
            let owned = Json::parse(text);
            let slice = JsonSlice::parse(text);
            ensure_eq(
                owned.is_ok(),
                slice.is_ok(),
                "owned and slice paths agree on accept/reject",
            )?;
            match owned {
                Ok(v) => {
                    // Accepted soup must reach a serialization fixpoint.
                    let s = v.to_string();
                    ensure_eq(
                        Json::parse(&s).map_err(|e| e.to_string())?,
                        v,
                        "reparse of reserialized soup",
                    )?;
                    ensure_eq(
                        slice.unwrap().to_owned().to_string(),
                        s,
                        "slice path serializes identically",
                    )
                }
                Err(e) => {
                    // Error positions stay self-consistent: offset in
                    // bounds, line/col 1-based and derivable from offset.
                    ensure(e.offset <= text.len(), format!("offset {} out of bounds", e.offset))?;
                    let prefix = &text.as_bytes()[..e.offset];
                    let line = 1 + prefix.iter().filter(|&&b| b == b'\n').count();
                    let col = 1 + prefix.iter().rev().take_while(|&&b| b != b'\n').count();
                    ensure_eq(e.line, line, "line derives from offset")?;
                    ensure_eq(e.col, col, "col derives from offset")
                }
            }
        },
    );
}

// -------------------------------------------------------------------- quant

fn gen_matrix(rng: &mut Rng, max_dim: usize) -> (Vec<f32>, usize, usize) {
    let k = rng.range(1, max_dim) * 2; // even K so int4 packing applies too
    let n = rng.range(1, max_dim);
    let scale = 10f64.powi(rng.range(0, 6) as i32 - 3);
    let w: Vec<f32> = (0..k * n).map(|_| (rng.normal() * scale) as f32).collect();
    (w, k, n)
}

#[test]
fn prop_int8_roundtrip_error_is_bounded_by_half_scale() {
    check(
        "int8-weight-roundtrip",
        200,
        0xF022_0008,
        |rng| gen_matrix(rng, 8),
        |(w, k, n)| {
            let (q, scales) = int8::quant_weight_per_channel(w, *k, *n);
            let dq = int8::dequant_per_channel(&q, &scales, *k, *n);
            for row in 0..*k {
                for col in 0..*n {
                    let (x, y) = (w[row * n + col], dq[row * n + col]);
                    // Half the quantization step, plus slack for f32
                    // division/product rounding at large magnitudes.
                    let bound = scales[col] * 0.5001 + 1e-6;
                    ensure(
                        (x - y).abs() <= bound,
                        format!("w[{row},{col}]={x} dequants to {y}, bound {bound}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_int8_activation_roundtrip_error_is_bounded_by_half_scale() {
    check(
        "int8-act-roundtrip",
        200,
        0xF022_0009,
        |rng| {
            let m = rng.range(1, 8);
            let k = rng.range(1, 8);
            let x: Vec<f32> = (0..m * k).map(|_| (rng.normal() * 4.0) as f32).collect();
            (x, m, k)
        },
        |(x, m, k)| {
            let (q, scales) = int8::quant_act_per_token(x, *m, *k);
            for row in 0..*m {
                for col in 0..*k {
                    let v = x[row * k + col];
                    let dq = q[row * k + col] as f32 * scales[row];
                    let bound = scales[row] * 0.5001 + 1e-6;
                    ensure(
                        (v - dq).abs() <= bound,
                        format!("x[{row},{col}]={v} dequants to {dq}, bound {bound}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_int4_pack_unpack_is_the_identity() {
    check(
        "int4-pack-unpack",
        200,
        0xF022_000A,
        |rng| {
            let k = rng.range(1, 8) * 2;
            let n = rng.range(1, 8);
            let q: Vec<i8> = (0..k * n).map(|_| rng.range(0, 14) as i8 - 7).collect();
            (q, k, n)
        },
        |(q, k, n)| {
            let packed = int4::pack(q, *k, *n);
            ensure_eq(packed.len(), k / 2 * n, "packed size halves K")?;
            ensure_eq(int4::unpack(&packed, k / 2, *n), q.clone(), "unpack(pack(q)) == q")
        },
    );
}

#[test]
fn prop_int4_roundtrip_error_is_bounded_by_half_scale() {
    check(
        "int4-weight-roundtrip",
        200,
        0xF022_000B,
        |rng| gen_matrix(rng, 8),
        |(w, k, n)| {
            let (q, scales) = int4::quant_weight_per_channel(w, *k, *n);
            let restored = int4::unpack(&int4::pack(&q, *k, *n), k / 2, *n);
            ensure_eq(restored, q.clone(), "pack survives the quantized grid")?;
            for row in 0..*k {
                for col in 0..*n {
                    let x = w[row * n + col];
                    let dq = q[row * n + col] as f32 * scales[col];
                    let bound = scales[col] * 0.5001 + 1e-6;
                    ensure(
                        (x - dq).abs() <= bound,
                        format!("w[{row},{col}]={x} dequants to {dq}, bound {bound}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}
