//! De-risk integration test for the AOT interface decisions (DESIGN.md §3):
//!   * HLO text with multiple parameters keeps jit argument order
//!   * single flat f32 output (logits ++ kv-state) -> one non-tuple buffer
//!   * i8 parameters accepted via untyped literals
//!   * the output buffer feeds back as the state input via execute_b
//!     (device-resident KV pattern) without any host round trip
//!   * partial host copy of just the logits prefix via copy_raw_to_host_sync
//!
//! Skips (passes trivially) when the generated HLO file is absent.

use anyhow::Result;

#[test]
fn flat_state_roundtrip_and_buffer_feedback() -> Result<()> {
    let path = "/tmp/derisk/fn.hlo.txt";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} not generated");
        return Ok(());
    }
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;

    // fn(w f32[4,4], wq i8[4,4], tok i32[2], state f32[16]) -> f32[16]
    //   out[0..8]  = [sum(kv,axis=1), max(kv,axis=1), 0, 0, 0, 0]
    //   out[8..16] = new kv = old kv + (w[tok] + wq[tok])
    let w: Vec<f32> = (0..16).map(|i| i as f32).collect();
    let w_lit = xla::Literal::vec1(&w).reshape(&[4, 4])?;
    let wq_lit = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        &[4, 4],
        &[1u8; 16],
    )?;
    let tok = xla::Literal::vec1(&[1i32, 3i32]);
    let state0 = xla::Literal::vec1(&[0f32; 16]);

    let w_b = client.buffer_from_host_literal(None, &w_lit)?;
    let wq_b = client.buffer_from_host_literal(None, &wq_lit)?;
    let tok_b = client.buffer_from_host_literal(None, &tok)?;
    let state_b = client.buffer_from_host_literal(None, &state0)?;

    // Tiny readout executable: state f32[16] -> logits f32[8] (prefix slice).
    let ro_proto = xla::HloModuleProto::from_text_file("/tmp/derisk/readout.hlo.txt")?;
    let readout = client.compile(&xla::XlaComputation::from_proto(&ro_proto))?;

    let outs = exe.execute_b(&[&w_b, &wq_b, &tok_b, &state_b])?;
    assert_eq!(outs[0].len(), 1, "expected one flat output buffer");

    // Logits via the readout executable: only 8 floats cross to host.
    let ro = readout.execute_b(&[&outs[0][0]])?;
    let logits = ro[0][0].to_literal_sync()?.to_vec::<f32>()?;
    // kv row0 = [4,5,6,7]+1 = [5,6,7,8]: sum 26 max 8; row1 = [13,14,15,16]: sum 58 max 16
    assert_eq!(&logits[..4], &[26.0, 58.0, 8.0, 16.0]);

    // Feed the state back (device-resident): sums double.
    let outs2 = exe.execute_b(&[&w_b, &wq_b, &tok_b, &outs[0][0]])?;
    let ro2 = readout.execute_b(&[&outs2[0][0]])?;
    let logits2 = ro2[0][0].to_literal_sync()?.to_vec::<f32>()?;
    assert_eq!(&logits2[..4], &[52.0, 116.0, 16.0, 32.0]);

    println!("derisk flat-state roundtrip OK");
    Ok(())
}

#[test]
fn artifact_prefill_executes() -> Result<()> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return Ok(());
    }
    let client = xla::PjRtClient::cpu()?;
    let proto =
        xla::HloModuleProto::from_text_file(dir.join("exe/1b-sim_fp16_prefill_b8.hlo.txt").to_str().unwrap())?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    eprintln!("compiled");
    let tensors = pangu_atlas_quant::runtime::weights::read_pten(&dir.join("weights/1b-sim_fp16.pten"))?;
    eprintln!("read {} tensors", tensors.len());
    let mut bufs = Vec::new();
    let mut lits = Vec::new(); // keep host literals alive: the PJRT upload
                               // may read them asynchronously
    for t in &tensors {
        let lit = t.to_literal()?;
        bufs.push(client.buffer_from_host_literal(None, &lit)?);
        lits.push(lit);
    }
    eprintln!("uploaded");
    let tokens = vec![0i32; 8 * 48];
    eprintln!("a: vec1");
    let tok_r1 = xla::Literal::vec1(&tokens);
    eprintln!("b: reshape");
    let tok_lit = tok_r1.reshape(&[8, 48])?;
    eprintln!("c: len lit");
    let len_lit = xla::Literal::vec1(&[5i32, 5, 5, 5, 5, 5, 5, 5]);
    eprintln!("d: tok upload");
    let tok_b = client.buffer_from_host_literal(None, &tok_lit)?;
    eprintln!("e: len upload");
    let len_b = client.buffer_from_host_literal(None, &len_lit)?;
    eprintln!("inputs ready");
    let mut inputs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    inputs.push(&tok_b);
    inputs.push(&len_b);
    let outs = exe.execute_b(&inputs)?;
    eprintln!("executed: {} outputs", outs[0].len());
    let shape = outs[0][0].on_device_shape()?;
    eprintln!("shape: {shape:?}");
    // readout path (engine hot loop)
    let ro_proto = xla::HloModuleProto::from_text_file(
        dir.join("exe/1b-sim_readout_b8.hlo.txt").to_str().unwrap())?;
    let ro = client.compile(&xla::XlaComputation::from_proto(&ro_proto))?;
    eprintln!("readout compiled");
    let ro_outs = ro.execute_b(&[&outs[0][0]])?;
    eprintln!("readout executed");
    let logits = ro_outs[0][0].to_literal_sync()?.to_vec::<f32>()?;
    eprintln!("logits fetched: {} values, first {:?}", logits.len(), &logits[..4]);
    let lit = outs[0][0].to_literal_sync()?;
    eprintln!("big state fetch ok: len {}", lit.element_count());
    Ok(())
}
