//! End-to-end serving tests.
//!
//! The mock-backed tests exercise the FULL server loop (channel ->
//! admission queue -> continuous scheduler -> streamed responses) with no
//! `Runtime`/artifacts: the server is generic over its backend provider.
//! The artifact-backed test at the bottom drives the same stack over the
//! real PJRT runtime and skips when artifacts are absent.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use pangu_atlas_quant::atlas::perf_model::TokenInflation;
use pangu_atlas_quant::bench_suite::dataset::Benchmark;
use pangu_atlas_quant::bench_suite::scoring;
use pangu_atlas_quant::coordinator::admission::{AdmissionQueue, AdmitConfig};
use pangu_atlas_quant::coordinator::cost::{
    AtlasCostModel, CostModel, GrowContext, SlotStepCostModel,
};
use pangu_atlas_quant::coordinator::kv::KvConfig;
use pangu_atlas_quant::coordinator::request::Request;
use pangu_atlas_quant::coordinator::scheduler::{
    AdmitGate, LadderConfig, PreemptConfig, SchedReport, Scheduler, SchedulerConfig,
};
use pangu_atlas_quant::coordinator::slo::{SloPolicy, SloSnapshot};
use pangu_atlas_quant::quant::Precision;
use pangu_atlas_quant::coordinator::server::Server;
use pangu_atlas_quant::runtime::backend::{MockBackend, MockProvider};
use pangu_atlas_quant::runtime::Runtime;
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};

// ---------------------------------------------------------------------------
// Mock-backed server tests (no artifacts, run everywhere)
// ---------------------------------------------------------------------------

/// Scripted mock model (shared helper): slow_think prompts produce a
/// `long`-token trace, everything else a 3-token completion.
fn mock_provider(
    tk: &Tokenizer,
    long: usize,
) -> MockProvider<impl Fn(&[i32]) -> Vec<u32>> {
    let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(tk, long);
    MockProvider::new(MockBackend::new(64, 48, 96, script))
}

fn request(id: u64, mode: CotMode) -> Request {
    let ex = vec![
        (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]),
        (vec![0, 1, 2, 3, 4], vec![4, 3, 2, 1, 0]),
    ];
    Request::new(id, "7b-sim", "int8", mode, ex)
}

/// Full server loop over MockBackend: a queued request joins mid-decode
/// once a short request frees its slot, and the short request's response
/// is delivered (strictly earlier) while the slow_think request is still
/// decoding.
#[test]
fn mock_server_joins_and_streams_responses() -> Result<()> {
    let tk = Tokenizer::minilang_default();
    let (mut server, handle) = Server::new(
        mock_provider(&tk, 16),
        &tk,
        SchedulerConfig::fixed(2, AdmitGate::Continuous),
        AdmitConfig::with_wait(false, Duration::from_millis(50)),
    );

    // All three requests are queued before the session starts; the bucket
    // holds two, so request 2 must join mid-flight when request 1's slot
    // frees — long before request 0 (slow_think) finishes.
    let rx0 = handle.submit(request(0, CotMode::SlowThink))?;
    let rx1 = handle.submit(request(1, CotMode::NoThink))?;
    let rx2 = handle.submit(request(2, CotMode::NoThink))?;
    drop(handle);

    let processed = server.run_until_idle(Duration::from_millis(200))?;
    assert_eq!(processed, 3);

    let r0 = rx0.recv()?;
    let r1 = rx1.recv()?;
    let r2 = rx2.recv()?;
    assert_eq!((r0.id, r1.id, r2.id), (0, 1, 2), "replies matched by id");
    assert_eq!(r0.tokens.len(), 16);
    assert_eq!(r1.tokens.len(), 3);
    assert_eq!(r2.tokens.len(), 3);
    // Streaming delivery: both short responses completed strictly before
    // the slow_think one (their latencies are snapshots taken at delivery).
    assert!(r1.latency_ms < r0.latency_ms, "short delivered before long finished");
    assert!(r2.latency_ms < r0.latency_ms, "late join delivered before long finished");
    // The late request really was admitted into the running batch.
    assert!(server.metrics.counter("joins") >= 1, "no mid-flight join happened");
    assert_eq!(server.metrics.counter("requests_served"), 3);
    assert!(server.metrics.counter("sessions") >= 1);
    let backend = server.into_provider().backend;
    assert!(backend.joins >= 1);
    assert_eq!(backend.prefills, 1, "one batch prefill; admissions are joins");
    Ok(())
}

/// The acceptance benchmark: the same mixed no_think/slow_think workload
/// with staggered admission costs fewer total decode slot-steps under the
/// continuous scheduler than under the wave-equivalent barrier, and its
/// occupancy beats the wave batch efficiency.
#[test]
fn mock_server_continuous_beats_wave_equivalent() -> Result<()> {
    let run = |gate: AdmitGate| -> Result<(u64, f64)> {
        let tk = Tokenizer::minilang_default();
        let (mut server, handle) = Server::new(
            mock_provider(&tk, 12),
            &tk,
            SchedulerConfig::fixed(2, gate),
            AdmitConfig::with_wait(false, Duration::from_millis(50)),
        );
        let rxs: Vec<_> = [
            request(0, CotMode::SlowThink), // 12-token straggler
            request(1, CotMode::NoThink),
            request(2, CotMode::NoThink),
            request(3, CotMode::NoThink),
        ]
        .into_iter()
        .map(|r| handle.submit(r).unwrap())
        .collect();
        drop(handle);
        let processed = server.run_until_idle(Duration::from_millis(200))?;
        assert_eq!(processed, 4);
        for rx in rxs {
            assert!(!rx.recv()?.tokens.is_empty());
        }
        let steps = server.metrics.counter("decode_steps");
        let occupancy = server.metrics.summary("occupancy").expect("occupancy observed").mean;
        Ok((steps, occupancy))
    };
    let (cont_steps, cont_occ) = run(AdmitGate::Continuous)?;
    let (wave_steps, wave_occ) = run(AdmitGate::WaveBarrier)?;
    // Same bucket both ways, so fewer decode steps == fewer slot-steps.
    assert!(
        cont_steps < wave_steps,
        "continuous {cont_steps} decode steps !< wave {wave_steps}"
    );
    assert!(
        cont_occ > wave_occ,
        "continuous occupancy {cont_occ:.3} !> wave batch efficiency {wave_occ:.3}"
    );
    Ok(())
}

/// Mode-aware admission: with one slot, queued no_think requests are
/// admitted ahead of an earlier slow_think request (within the aging
/// bound), and every reply still reaches its own caller by id.
#[test]
fn mock_server_mode_aware_admission_keeps_replies_matched() -> Result<()> {
    let tk = Tokenizer::minilang_default();
    let (mut server, handle) = Server::new(
        mock_provider(&tk, 12),
        &tk,
        SchedulerConfig::fixed(1, AdmitGate::Continuous),
        AdmitConfig::with_wait(true, Duration::from_secs(10)),
    );
    let rx_slow = handle.submit(request(7, CotMode::SlowThink))?;
    let rx_fast = handle.submit(request(8, CotMode::NoThink))?;
    drop(handle);
    server.run_until_idle(Duration::from_millis(200))?;
    // The no_think request overtook the earlier slow_think in admission
    // order, yet each caller got its own response (keyed by id, not queue
    // position).
    let slow = rx_slow.recv()?;
    let fast = rx_fast.recv()?;
    assert_eq!(slow.id, 7);
    assert_eq!(fast.id, 8);
    assert_eq!(slow.tokens.len(), 12);
    assert_eq!(fast.tokens.len(), 3);
    assert!(
        fast.latency_ms < slow.latency_ms,
        "mode-aware admission should finish the short request first"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Adaptive bucket ladder: trickle -> burst -> trickle ramp
// ---------------------------------------------------------------------------

/// Deterministic ramp driven at scheduler level (arrivals injected at exact
/// pump ticks, greedy decoding, scripted mock): the acceptance benchmark of
/// the adaptive ladder. `(tokens, first_token_step)` per request id plus the
/// session report.
fn ramp_run(buckets: Vec<usize>) -> (BTreeMap<u64, (Vec<u32>, usize)>, SchedReport) {
    ramp_run_with_cost(buckets, Arc::new(SlotStepCostModel))
}

/// [`ramp_run`] with an explicit ladder cost model (the cost-model
/// acceptance test compares policies under identical pricing).
fn ramp_run_with_cost(
    buckets: Vec<usize>,
    cost: Arc<dyn CostModel>,
) -> (BTreeMap<u64, (Vec<u32>, usize)>, SchedReport) {
    let tk = Tokenizer::minilang_default();
    let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
    let mut be = MockBackend::new(64, 48, 96, script);
    let sched = Scheduler::new(
        &tk,
        SchedulerConfig {
            buckets,
            gate: AdmitGate::Continuous,
            ladder: LadderConfig { eval_every: 2, shrink_patience: 2, ..LadderConfig::default() },
            ..SchedulerConfig::default()
        }
        .with_cost(cost),
    );
    let mut queue = AdmissionQueue::new(AdmitConfig::with_wait(false, Duration::ZERO));
    // Phase 1 (trickle): a 30-token slow_think straggler that keeps the
    // session alive across all three phases, plus one short request.
    queue.push(request(0, CotMode::SlowThink));
    queue.push(request(1, CotMode::NoThink));
    let mut pumps = 0usize;
    let mut out: BTreeMap<u64, (Vec<u32>, usize)> = BTreeMap::new();
    let report = sched
        .run(
            &mut be,
            &mut queue,
            &mut |q| {
                pumps += 1;
                if pumps == 9 {
                    // Phase 2 (burst): eight arrivals land at once, two of
                    // them slow_think.
                    for id in 2..10 {
                        let mode =
                            if id % 4 == 0 { CotMode::SlowThink } else { CotMode::NoThink };
                        q.push(request(id, mode));
                    }
                }
                if pumps == 31 {
                    // Phase 3 (back to a trickle).
                    q.push(request(10, CotMode::NoThink));
                    q.push(request(11, CotMode::NoThink));
                }
            },
            &mut |r| {
                out.insert(r.id, (r.tokens, r.first_token_step));
            },
        )
        .expect("ramp session");
    (out, report)
}

/// The ISSUE acceptance test: on the trickle -> burst -> trickle ramp the
/// adaptive ladder charges strictly fewer slot-steps than a fixed
/// `max(buckets)` session, migrates both up and down, keeps burst TTFT no
/// worse than the fixed run (step-clock, within the grow latency bound),
/// and produces byte-identical outputs.
#[test]
fn ramp_adaptive_ladder_beats_fixed_max_bucket() {
    let (adaptive_out, adaptive) = ramp_run(vec![2, 4, 8]);
    let (fixed_out, fixed) = ramp_run(vec![8]);

    assert_eq!(adaptive.completed, 12);
    assert_eq!(fixed.completed, 12);
    assert_eq!(adaptive_out.len(), 12, "no request lost");
    assert!(
        adaptive.slot_steps() < fixed.slot_steps(),
        "adaptive {} slot-steps !< fixed {}",
        adaptive.slot_steps(),
        fixed.slot_steps()
    );
    assert!(adaptive.migrations_up >= 1, "burst must grow the session");
    assert!(adaptive.migrations_down >= 1, "drained phases must shrink it");
    assert!(adaptive.occupancy() > fixed.occupancy());
    // Growth is eager (it costs no decode steps), so admission latency is
    // preserved: every request's first token lands within the grow bound
    // of the fixed max-bucket run, burst arrivals included.
    for (id, (tokens, ttft_steps)) in &adaptive_out {
        let (fixed_tokens, fixed_ttft_steps) = &fixed_out[id];
        assert_eq!(tokens, fixed_tokens, "request {id} output diverged across ladders");
        assert!(
            *ttft_steps <= fixed_ttft_steps + 2,
            "request {id}: adaptive first token at step {ttft_steps}, \
             fixed at {fixed_ttft_steps}"
        );
    }
}

/// Atlas pricing with the pre-cost-model policy: unconditional growth and
/// the one-rung shrink walk — the "occupancy-only ladder" baseline, priced
/// in the same modeled milliseconds as the Atlas-policy run so their
/// modeled totals are directly comparable.
#[derive(Debug)]
struct OccupancyOnlyAtlasPriced(AtlasCostModel);

impl CostModel for OccupancyOnlyAtlasPriced {
    fn decode_step_ms(&self, precision: Precision, bucket: usize) -> f64 {
        self.0.decode_step_ms(precision, bucket)
    }
    fn prefill_ms(&self, precision: Precision, bucket: usize) -> f64 {
        self.0.prefill_ms(precision, bucket)
    }
    fn shrink_target(
        &self,
        precision: Precision,
        buckets: &[usize],
        rung: usize,
        occupied: usize,
    ) -> Option<usize> {
        SlotStepCostModel.shrink_target(precision, buckets, rung, occupied)
    }
    fn grow_pays_off(&self, _precision: Precision, ctx: GrowContext) -> bool {
        ctx.queued > 0
    }
}

/// The ISSUE 3 acceptance test: the trickle -> burst -> trickle ramp under
/// [`AtlasCostModel`] ends with a modeled total latency no worse than the
/// occupancy-only ladder's (both priced in Atlas milliseconds), shrinks
/// straight to its target rung in ONE migration, and still produces outputs
/// byte-identical to the fixed max-bucket baseline.
#[test]
fn ramp_atlas_cost_model_beats_occupancy_only_ladder() {
    let (atlas_out, atlas) =
        ramp_run_with_cost(vec![2, 4, 8], Arc::new(AtlasCostModel::openpangu_7b()));
    let (occ_out, occ) = ramp_run_with_cost(
        vec![2, 4, 8],
        Arc::new(OccupancyOnlyAtlasPriced(AtlasCostModel::openpangu_7b())),
    );
    let (fixed_out, _) = ramp_run(vec![8]);

    assert_eq!(atlas.completed, 12);
    assert_eq!(occ.completed, 12);
    // Modeled total latency: the cost-driven policy never does worse than
    // the occupancy-only walk under identical pricing (in practice it does
    // strictly better — the walk pays big-bucket rebuild prices).
    assert!(
        atlas.modeled_total_ms() <= occ.modeled_total_ms() + 1e-6,
        "atlas policy modeled {:.1} ms !<= occupancy-only {:.1} ms",
        atlas.modeled_total_ms(),
        occ.modeled_total_ms()
    );
    // Shrink reaches its target rung in one migration instead of walking.
    assert_eq!(
        atlas.migrations_down, 1,
        "cost-driven shrink must jump straight to the target rung"
    );
    assert!(occ.migrations_down >= 1, "the baseline ladder still shrinks");
    assert!(
        atlas.modeled_migrate_ms > 0.0,
        "migrations must be priced into the modeled account"
    );
    // Rung policy never changes what is generated.
    assert_eq!(atlas_out.len(), 12, "no request lost");
    for (id, (tokens, _)) in &atlas_out {
        assert_eq!(
            tokens, &fixed_out[id].0,
            "request {id} output diverged from the fixed max-bucket baseline"
        );
        assert_eq!(tokens, &occ_out[id].0, "request {id} diverged across policies");
    }
}

/// The same ramp shape through the full mock server (channel front-end,
/// client thread, wall-clock arrival gaps): the adaptive ladder serves the
/// whole workload and charges strictly fewer slot-steps than fixed
/// `max(buckets)`.
#[test]
fn mock_server_ramp_charges_fewer_slot_steps_adaptively() -> Result<()> {
    let run = |cfg: SchedulerConfig| -> Result<(u64, f64)> {
        let tk = Tokenizer::minilang_default();
        let (mut server, handle) = Server::new(
            mock_provider(&tk, 30),
            &tk,
            cfg,
            AdmitConfig::with_wait(false, Duration::from_millis(2)),
        );
        let client = std::thread::spawn(move || {
            let mut rxs = Vec::new();
            // Trickle.
            rxs.push(handle.submit(request(0, CotMode::SlowThink)).unwrap());
            rxs.push(handle.submit(request(1, CotMode::NoThink)).unwrap());
            std::thread::sleep(Duration::from_millis(10));
            // Burst.
            for id in 2..12 {
                rxs.push(handle.submit(request(id, CotMode::NoThink)).unwrap());
            }
            std::thread::sleep(Duration::from_millis(10));
            // Back to a trickle.
            rxs.push(handle.submit(request(12, CotMode::NoThink)).unwrap());
            drop(handle);
            rxs.into_iter().map(|rx| rx.recv().unwrap()).collect::<Vec<_>>()
        });
        let processed = server.run_until_idle(Duration::from_millis(100))?;
        let resps = client.join().expect("client thread");
        assert_eq!(processed, 13);
        assert_eq!(resps.len(), 13);
        for r in &resps {
            assert!(!r.tokens.is_empty());
        }
        // Wall-clock TTFT of the burst arrivals (ids 2..12); the
        // deterministic step-clock bound lives in
        // ramp_adaptive_ladder_beats_fixed_max_bucket.
        let burst_ttft = resps
            .iter()
            .filter(|r| (2..12).contains(&r.id))
            .map(|r| r.ttft_ms)
            .fold(0f64, f64::max);
        Ok((server.metrics.counter("slot_steps"), burst_ttft))
    };
    let (adaptive_steps, adaptive_ttft) =
        run(SchedulerConfig::ladder(vec![2, 4, 8], AdmitGate::Continuous)?)?;
    let (fixed_steps, fixed_ttft) = run(SchedulerConfig::fixed(8, AdmitGate::Continuous))?;
    assert!(
        adaptive_steps < fixed_steps,
        "adaptive {adaptive_steps} slot-steps !< fixed {fixed_steps}"
    );
    // Coarse wall-clock sanity only (scheduling noise makes tight bounds
    // flaky): growing eagerly must not add human-visible burst latency.
    assert!(
        adaptive_ttft <= fixed_ttft + 50.0,
        "burst TTFT regressed: adaptive {adaptive_ttft:.2}ms vs fixed {fixed_ttft:.2}ms"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Paged KV block pool: token-granular vs whole-window reservation
// ---------------------------------------------------------------------------

/// The ISSUE 4 acceptance test. Under the SAME modeled HBM budget (16 KV
/// pages of 16 tokens), a long-CoT `slow_think` workload:
///
///   * the **paged** pool admits strictly more concurrent sequences than
///     the **whole-window** baseline (which burns a full 6-page `max_seq`
///     window per admission),
///   * defers strictly fewer admissions,
///   * and produces outputs byte-identical to the unbounded slot-granular
///     scheduler — while the mock backend's block contract (no page mapped
///     by two live slots) is enforced on every publication.
#[test]
fn paged_pool_outadmits_whole_window_under_same_hbm_budget() {
    // Long-CoT workload: every request is a 30-token slow_think trace over
    // a 28-token prompt, so a sequence peaks at 4 pages — far under the
    // 6-page whole-window reservation.
    let workload = || -> Vec<Request> {
        (0..6).map(|id| request(id, CotMode::SlowThink)).collect()
    };
    let budget_tokens = 16 * 16;
    let run = |kv_cfg: Option<KvConfig>| {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
        let mut be = MockBackend::new(64, 48, 96, script);
        let mut cfg = SchedulerConfig::fixed(3, AdmitGate::Continuous);
        if let Some(kv_cfg) = kv_cfg {
            cfg = cfg.with_kv(kv_cfg);
        }
        let sched = Scheduler::new(&tk, cfg);
        let (resps, report) = sched.run_batch(&mut be, &workload()).expect("session");
        assert_eq!(resps.len(), 6, "every request answered");
        assert!(be.binds > 0, "block tables were published to the backend");
        (resps, report)
    };

    let (baseline_resps, baseline) = run(None); // unbounded slot-granular
    let (window_resps, window) = run(Some(KvConfig::whole_window(16, budget_tokens)));
    let (paged_resps, paged) = run(Some(KvConfig::paged(16, budget_tokens)));

    // Everyone completes everywhere: the budget defers, it never drops.
    for report in [&baseline, &window, &paged] {
        assert_eq!(report.completed, 6);
        assert_eq!(report.rejected, 0);
    }
    // Token-granular reservation admits strictly more concurrent long-CoT
    // sequences than whole-window reservation under the same budget.
    assert!(
        paged.max_live > window.max_live,
        "paged max_live {} !> whole-window {}",
        paged.max_live,
        window.max_live
    );
    // ...and defers strictly fewer admissions.
    assert!(
        paged.deferred < window.deferred,
        "paged deferred {} !< whole-window {}",
        paged.deferred,
        window.deferred
    );
    assert!(window.deferred >= 1, "the baseline must actually hit the budget");
    // The budget never bent the generation: the paged run is byte-identical
    // to the unbounded slot-granular scheduler.
    assert_eq!(paged.max_live, baseline.max_live, "budget did not gate the paged run");
    for (p, b) in paged_resps.iter().zip(&baseline_resps) {
        assert_eq!(p.id, b.id);
        assert_eq!(p.tokens, b.tokens, "request {} diverged under paging", p.id);
        assert!(!p.truncated, "no pool-exhaustion truncation in the paged run");
    }
    // The whole-window run also generates identical bytes — it is merely
    // slower to admit (serialized by reservation, visible in slot-steps).
    for (w, b) in window_resps.iter().zip(&baseline_resps) {
        assert_eq!(w.tokens, b.tokens);
    }
    assert!(
        paged.slot_steps() < window.slot_steps(),
        "concurrency gain must show up as fewer slot-steps: paged {} vs window {}",
        paged.slot_steps(),
        window.slot_steps()
    );
    // Pool accounting: token-granular reservation pays 4 pages per
    // sequence (prompt + trace) where the window pays 6, and every page
    // comes back.
    assert!(
        paged.kv_pages_allocated < window.kv_pages_allocated,
        "paged {} pages !< whole-window {}",
        paged.kv_pages_allocated,
        window.kv_pages_allocated
    );
    assert_eq!(paged.kv_pages_allocated, paged.kv_pages_released);
    assert!(paged.kv_peak_pool_util > 0.0 && window.kv_peak_pool_util > 0.0);
}

/// The ISSUE 7 acceptance test. An n-best workload — eight requests over
/// one shared preamble (identical 41-token prompts, 3 pages each) —
/// under the SAME 6-page budget:
///
///   * the **shared-prefix CoW** pool admits strictly more concurrent
///     sequences than the plain paged pool (sharers retain the donor's
///     prompt pages instead of allocating their own) and defers strictly
///     fewer admissions;
///   * every sharer's first decode write forks a private boundary page —
///     and the page-aware mock rejects any advancing write into a
///     multi-mapped page, so a clean run proves no write-through ever
///     reached the backend;
///   * sharing never bends generation: outputs are byte-identical to the
///     unbounded slot-granular run.
#[test]
fn shared_prefix_cow_outadmits_plain_paging_on_nbest_workload() {
    let nbest_request = |id: u64| -> Request {
        let ex = vec![
            (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]),
            (vec![0, 1, 2, 3, 4], vec![4, 3, 2, 1, 0]),
            (vec![2, 3, 4, 5, 6], vec![6, 5, 4, 3, 2]),
        ];
        Request::new(id, "7b-sim", "int8", CotMode::NoThink, ex)
    };
    let workload = || -> Vec<Request> { (0..8).map(nbest_request).collect() };
    // 6 pages: one donor's 3 prompt pages + 3 CoW forks fit exactly; the
    // plain pool can hold only two whole 3-page prompts at once.
    let budget_tokens = 6 * 16;
    let run = |kv_cfg: Option<KvConfig>| {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 6);
        let share = kv_cfg.as_ref().map_or(false, |c| c.sharing());
        let mut be = MockBackend::new(64, 48, 96, script);
        if share {
            be = be.with_page_tokens(16);
        }
        let mut cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous);
        if let Some(kv_cfg) = kv_cfg {
            cfg = cfg.with_kv(kv_cfg);
        }
        let sched = Scheduler::new(&tk, cfg);
        let (resps, report) = sched.run_batch(&mut be, &workload()).expect("session");
        assert_eq!(resps.len(), 8, "every request answered");
        (resps, report)
    };

    let (baseline_resps, baseline) = run(None); // unbounded slot-granular
    let (plain_resps, plain) = run(Some(KvConfig::paged(16, budget_tokens)));
    let (shared_resps, shared) =
        run(Some(KvConfig::paged(16, budget_tokens).with_prefix_sharing()));

    for report in [&baseline, &plain, &shared] {
        assert_eq!(report.completed, 8);
        assert_eq!(report.rejected, 0);
    }
    // Sharing admits strictly more concurrent sequences than paying full
    // prompt pages per admission...
    assert!(
        shared.max_live > plain.max_live,
        "shared max_live {} !> plain paged {}",
        shared.max_live,
        plain.max_live
    );
    // ...and defers strictly fewer admissions under the same budget.
    assert!(plain.deferred >= 1, "the plain pool must actually hit the budget");
    assert!(
        shared.deferred < plain.deferred,
        "shared deferred {} !< plain {}",
        shared.deferred,
        plain.deferred
    );
    // The sharing story is visible in the counters: every admission after
    // a donor maps cached pages by reference, and each sharer's first
    // write forks exactly one private boundary page.
    assert!(shared.kv_prefix_hits >= 6, "prefix hits {} < 6", shared.kv_prefix_hits);
    assert!(shared.kv_shared_pages_reused >= 9, "reused {} < 9", shared.kv_shared_pages_reused);
    assert!(shared.kv_cow_forks >= 3, "CoW forks {} < 3", shared.kv_cow_forks);
    assert_eq!(plain.kv_prefix_hits, 0, "plain paging never shares");
    assert_eq!(plain.kv_cow_forks, 0, "plain paging never forks");
    // Reference-counted reuse means fewer unique pages ever allocated.
    assert!(
        shared.kv_pages_allocated < plain.kv_pages_allocated,
        "shared {} pages allocated !< plain {}",
        shared.kv_pages_allocated,
        plain.kv_pages_allocated
    );
    assert_eq!(
        shared.kv_pages_allocated, shared.kv_pages_released,
        "refcounted pool conserves pages"
    );
    // Sharing never bends generation: byte-identical to the unbounded run.
    for (s, b) in shared_resps.iter().zip(&baseline_resps) {
        assert_eq!(s.id, b.id);
        assert_eq!(s.tokens, b.tokens, "request {} diverged under sharing", s.id);
        assert!(!s.truncated, "no pool-exhaustion truncation under sharing");
    }
    for (p, b) in plain_resps.iter().zip(&baseline_resps) {
        assert_eq!(p.tokens, b.tokens, "request {} diverged under plain paging", p.id);
    }
    // Admitting the whole n-best group at once drains the workload in
    // strictly fewer slot-steps than serializing two-at-a-time.
    assert!(
        shared.slot_steps() < plain.slot_steps(),
        "concurrency gain must show up as fewer slot-steps: shared {} vs plain {}",
        shared.slot_steps(),
        plain.slot_steps()
    );
}

/// The ISSUE 5 acceptance test: the PR 4 `--long-cot` tight-budget
/// scenario (the same 16-page modeled HBM budget), pushed until the pool
/// genuinely starves mid-decode, run preempt-vs-truncate:
///
///   * the **truncate** baseline (the default policy) force-finishes at
///     least one long-CoT sequence — the paper's truncation failure;
///   * the **preempt** policy finishes every sequence `truncated == false`
///     with outputs byte-identical to an ample-pool run;
///   * the price is visible and accounted: `recomputed_tokens` > 0 and a
///     modeled-ms total no lower than the baseline's, printed below.
#[test]
fn preempt_policy_completes_long_cot_where_truncation_fails() {
    // Four concurrent 28-token slow_think prompts tracing 40 tokens peak at
    // 5 pages each (position 67) — 20 pages of demand against the same
    // 16-page budget as the PR 4 e2e, so the fourth page-crossing starves.
    let budget_tokens = 16 * 16;
    let workload = || -> Vec<Request> {
        (0..4).map(|id| request(id, CotMode::SlowThink)).collect()
    };
    let run = |kv_cfg: Option<KvConfig>, preempt: PreemptConfig| {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 40);
        let mut be = MockBackend::new(64, 48, 96, script);
        let mut cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous).with_preempt(preempt);
        if let Some(kv_cfg) = kv_cfg {
            cfg = cfg.with_kv(kv_cfg);
        }
        let sched = Scheduler::new(&tk, cfg);
        let (resps, report) = sched.run_batch(&mut be, &workload()).expect("session");
        assert_eq!(resps.len(), 4, "every caller answered");
        (resps, report, be.restores)
    };

    let (ample_resps, ample, _) = run(None, PreemptConfig::default());
    let (trunc_resps, trunc, _) =
        run(Some(KvConfig::paged(16, budget_tokens)), PreemptConfig::default());
    let (preempt_resps, preempt, restores) =
        run(Some(KvConfig::paged(16, budget_tokens)), PreemptConfig::enabled());

    // The baseline genuinely starves: at least one sequence truncated.
    let truncated = trunc_resps.iter().filter(|r| r.truncated).count();
    assert!(truncated >= 1, "the truncate baseline must hit the budget");
    assert_eq!(trunc.preemptions, 0, "the default policy never preempts");

    // The preempt policy finishes everyone, byte-identical to ample HBM.
    for (p, a) in preempt_resps.iter().zip(&ample_resps) {
        assert_eq!(p.id, a.id);
        assert!(!p.truncated, "request {} truncated under preemption", p.id);
        assert_eq!(p.tokens, a.tokens, "request {} diverged from the ample run", p.id);
    }
    assert_eq!(preempt.completed, 4);

    // Every preemption and recomputed token is accounted, and the recompute
    // bill shows up in the modeled device-cost total.
    assert!(preempt.preemptions >= 1, "completion was bought with a preemption");
    assert_eq!(restores, preempt.preemptions, "every eviction was restored");
    assert!(preempt.recomputed_tokens > 0);
    assert!(preempt.preempt_stall_steps >= 1, "the parked victim waited for pages");
    assert_eq!(
        preempt.kv_pages_allocated, preempt.kv_pages_released,
        "preempt/restore churn conserves the pool"
    );
    assert!(
        preempt.decode_steps >= trunc.decode_steps,
        "recompute cannot be cheaper than truncating"
    );
    assert_eq!(ample.preemptions, 0, "an ample pool never preempts");

    println!(
        "preempt-vs-truncate under a {budget_tokens}-token budget: \
         truncate baseline finished {}/{} untruncated (modeled {:.1} ms); \
         preempt finished 4/4 untruncated at a cost of {} preemption(s), \
         {} recomputed tokens, {} stall steps (modeled {:.1} ms)",
        4 - truncated,
        4,
        trunc.modeled_total_ms(),
        preempt.preemptions,
        preempt.recomputed_tokens,
        preempt.preempt_stall_steps,
        preempt.modeled_total_ms(),
    );
}

/// Token-weighted demand (the `AdmitConfig::token_weighted_demand` flag)
/// through the full server: long-prompt backlogs read as more demand, so
/// the ladder launches on a bigger rung than the count-based default.
#[test]
fn token_weighted_demand_launches_a_bigger_rung() -> Result<()> {
    let tk = Tokenizer::minilang_default();
    let long_prompt_request = |id: u64| {
        // Eight examples ≈ 106 prompt tokens (vs 28 for the short form).
        let ex: Vec<(Vec<u8>, Vec<u8>)> =
            (0..8).map(|_| (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1])).collect();
        Request::new(id, "7b-sim", "int8", CotMode::NoThink, ex)
    };
    let run = |admit_cfg: AdmitConfig| -> Result<u64> {
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
        let provider = MockProvider::new(MockBackend::new(64, 128, 192, script));
        let (mut server, handle) = Server::new(
            provider,
            &tk,
            SchedulerConfig::ladder(vec![2, 8], AdmitGate::Continuous)?,
            admit_cfg,
        );
        let rxs: Vec<_> = (0..2)
            .map(|id| handle.submit(long_prompt_request(id)).unwrap())
            .collect();
        drop(handle);
        server.run_until_idle(Duration::from_millis(200))?;
        for rx in rxs {
            assert!(!rx.recv()?.tokens.is_empty());
        }
        Ok(server.metrics.counter("slot_steps") / server.metrics.counter("decode_steps").max(1))
    };
    // Count-based: two queued requests -> demand 2 -> launch at bucket 2.
    let count_bucket = run(AdmitConfig::with_wait(false, Duration::ZERO))?;
    // Token-weighted: 2 x ceil(106/24) = 10 -> launch at bucket 8.
    let token_bucket =
        run(AdmitConfig::with_wait(false, Duration::ZERO).with_token_demand(24))?;
    assert_eq!(count_bucket, 2, "count-based demand launches the small rung");
    assert_eq!(token_bucket, 8, "token-weighted demand reflects prompt footprint");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fleet: multi-device serving behind the cost-priced router
// ---------------------------------------------------------------------------

/// Skewed fleet workload: long slow_think traces (3 examples, 35 prompt
/// tokens -> 3 pages) alternating with short no_think ones (2 examples,
/// 20 prompt tokens -> 2 pages). Round-robin folds all the expensive
/// requests onto one device.
fn skew_request(id: u64) -> Request {
    if id % 2 == 0 {
        let ex = vec![
            (vec![1, 2, 3, 4], vec![4, 3, 2, 1]),
            (vec![2, 3, 4, 5], vec![5, 4, 3, 2]),
            (vec![3, 4, 5, 6], vec![6, 5, 4, 3]),
        ];
        Request::new(id, "7b-sim", "int8", CotMode::SlowThink, ex)
    } else {
        let ex = vec![(vec![1, 2, 3], vec![3, 2, 1]), (vec![2, 3, 4], vec![4, 3, 2])];
        Request::new(id, "7b-sim", "int8", CotMode::NoThink, ex)
    }
}

/// The ISSUE 6 acceptance test. Two devices with EQUAL per-device KV
/// budgets (10 pages each — the same total HBM either way), skewed
/// arrivals:
///
///   * **round-robin** sends every slow_think to device 0 (4 x 3 pages =
///     12 > 10), so its pool must defer admissions while device 1's sits
///     half empty;
///   * the **cost-priced** router interleaves placements (2 slow + 2
///     short = exactly 10 pages per device), defers strictly fewer
///     admissions, and models no more total milliseconds;
///   * placement never bends generation: both fleets' outputs are
///     byte-identical to a single unbounded bare-scheduler reference.
#[test]
fn fleet_cost_router_beats_round_robin_on_skewed_arrivals() {
    use pangu_atlas_quant::coordinator::fleet::{
        Fleet, FleetConfig, FleetReport, LeastLoadedRouter, RoundRobinRouter, RouterPolicy,
    };
    let tk = Tokenizer::minilang_default();
    let requests: Vec<Request> = (0..8).map(skew_request).collect();

    // Reference: one bare scheduler, unbounded pool — what every request
    // generates when nothing is budget-gated.
    let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 6);
    let mut be = MockBackend::new(64, 48, 96, script);
    let (reference, _) = Scheduler::new(&tk, SchedulerConfig::fixed(4, AdmitGate::Continuous))
        .run_batch(&mut be, &requests)
        .expect("reference session");

    let run = |policy: Box<dyn RouterPolicy>| -> FleetReport {
        let sched_cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous)
            .with_kv(KvConfig::paged(16, 10 * 16));
        let cfg = FleetConfig::homogeneous(
            2,
            sched_cfg,
            AdmitConfig::with_wait(false, Duration::ZERO),
        );
        let mut fleet = Fleet::new(&tk, cfg, policy).expect("fleet");
        let mut providers = vec![mock_provider(&tk, 6), mock_provider(&tk, 6)];
        let (resps, report) = fleet.run_batch(&mut providers, &requests).expect("fleet batch");
        assert_eq!(resps.len(), 8, "every request answered exactly once");
        for (resp, reference) in resps.iter().zip(&reference) {
            assert_eq!(resp.id, reference.id);
            assert_eq!(
                resp.tokens, reference.tokens,
                "request {} diverged under placement", resp.id
            );
            assert!(!resp.truncated, "request {} truncated by the budget", resp.id);
        }
        report
    };

    let cost = run(Box::new(LeastLoadedRouter::new()));
    let rr = run(Box::new(RoundRobinRouter::new()));

    assert_eq!(cost.rollup().completed, 8);
    assert_eq!(rr.rollup().completed, 8);
    // The skew-blind baseline genuinely overloads one pool...
    assert!(
        rr.rollup().deferred >= 1,
        "round-robin must overload device 0's pool on this workload"
    );
    // ...and the cost-priced router strictly beats it on deferrals while
    // modeling no more total milliseconds.
    assert!(
        cost.rollup().deferred < rr.rollup().deferred,
        "cost-priced deferred {} !< round-robin {}",
        cost.rollup().deferred,
        rr.rollup().deferred
    );
    assert!(
        cost.rollup().modeled_total_ms() <= rr.rollup().modeled_total_ms() + 1e-6,
        "cost-priced modeled {:.1} ms !<= round-robin {:.1} ms",
        cost.rollup().modeled_total_ms(),
        rr.rollup().modeled_total_ms()
    );
    // Balanced placement also shows up as fleet completion time: the
    // busiest device under the cost router finishes no later.
    assert!(
        cost.makespan_slot_steps() <= rr.makespan_slot_steps(),
        "cost makespan {} !<= round-robin {}",
        cost.makespan_slot_steps(),
        rr.makespan_slot_steps()
    );
    assert!(
        cost.imbalance_ratio() <= rr.imbalance_ratio(),
        "cost imbalance {:.3} !<= round-robin {:.3}",
        cost.imbalance_ratio(),
        rr.imbalance_ratio()
    );
    assert_eq!(cost.policy, "cost");
    assert_eq!(rr.policy, "round-robin");
}

/// Cross-device rebalance: a device whose pool starves mid-decode (its
/// preempted lane is non-empty) re-places its queued, not-yet-prefilled
/// work onto the sibling with headroom. Device 0 holds three growing
/// slow_think sequences against a 5-page pool; device 1 holds three
/// 1-page no_think requests. When device 0 starves and parks, its third
/// queued slow_think migrates to device 1 — and every request is still
/// answered exactly once, untruncated.
#[test]
fn fleet_rebalance_moves_queued_work_off_a_starved_device() {
    use pangu_atlas_quant::coordinator::fleet::{
        Fleet, FleetConfig, RoundRobinRouter,
    };
    let tk = Tokenizer::minilang_default();
    // Round-robin interleaving puts slows (even ids, 28-token prompts that
    // grow 16 tokens -> 2 pages then 3) on device 0 and tiny no_thinks
    // (11-token prompts, 1 page, no growth) on device 1.
    let requests: Vec<Request> = (0..6)
        .map(|id| {
            if id % 2 == 0 {
                request(id, CotMode::SlowThink)
            } else {
                let ex = vec![(vec![1, 2, 3], vec![3, 2, 1])];
                Request::new(id, "7b-sim", "int8", CotMode::NoThink, ex)
            }
        })
        .collect();
    let sched_cfg = SchedulerConfig::fixed(2, AdmitGate::Continuous)
        .with_kv(KvConfig::paged(16, 5 * 16))
        .with_preempt(PreemptConfig::enabled());
    let cfg = FleetConfig::homogeneous(
        2,
        sched_cfg,
        AdmitConfig::with_wait(false, Duration::ZERO),
    );
    let mut fleet = Fleet::new(&tk, cfg, Box::new(RoundRobinRouter::new())).expect("fleet");
    let mut providers = vec![mock_provider(&tk, 16), mock_provider(&tk, 16)];
    let (resps, report) = fleet.run_batch(&mut providers, &requests).expect("fleet batch");

    assert_eq!(resps.len(), 6, "every request answered exactly once");
    for (i, r) in resps.iter().enumerate() {
        assert_eq!(r.id, i as u64, "input order restored");
        assert!(!r.truncated, "request {i} truncated despite preempt + rebalance");
        let want = if i % 2 == 0 { 16 } else { 3 };
        assert_eq!(r.tokens.len(), want, "request {i} finished its full trace");
    }
    assert!(
        report.rebalances >= 1,
        "the starved device never re-placed its queued work"
    );
    assert_eq!(
        report.placements(),
        6,
        "placement accounting conserved through the move"
    );
    let total = report.rollup();
    assert_eq!(total.completed, 6);
    assert!(total.preemptions >= 1, "distress was real: the pool parked a sequence");
    assert_eq!(
        total.kv_pages_allocated, total.kv_pages_released,
        "fleet-wide page conservation through preempt + rebalance"
    );
    // The moved request really ran on the sibling: device 1 completed more
    // than its three original placements' worth of work.
    let d1 = &report.devices[1];
    assert!(
        d1.report.completed >= 4,
        "device 1 completed {} requests; expected the rebalanced one too",
        d1.report.completed
    );
}

// ---------------------------------------------------------------------------
// SLO-aware precision/mode selection (ISSUE 8)
// ---------------------------------------------------------------------------

/// The ISSUE 8 deadline gate. Four FP16 slow_think requests carry budgets
/// computed from the same inflation-honest cost model the scheduler prices
/// with: each budget is its own modeled queue wait plus the CHEAPEST
/// candidate's service time — strictly below the arrival pair's cost, so a
/// pinned scheduler cannot meet any of them:
///
///   * [`SloPolicy::pinned`] records a modeled miss on every admission
///     (4/4) and, running the un-degraded FP16 slow_think traces anyway,
///     starves the 16-page pool into truncation;
///   * [`SloPolicy::default`] degrades every request to the pair the
///     budget was derived from and meets every modeled deadline (>= 3/4
///     required; 0 misses achieved), serving untruncated;
///   * nobody is dropped either way.
#[test]
fn slo_deadline_gate_default_policy_meets_where_pinned_fp16_misses() {
    let tk = Tokenizer::minilang_default();
    let cost =
        AtlasCostModel::openpangu_7b().with_token_inflation(TokenInflation::a2_calibrated());
    let horizon = LadderConfig::default().grow_horizon;
    let arrival = (Precision::Fp16, CotMode::SlowThink);
    let fp16_request = |id: u64| {
        let ex = vec![
            (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]),
            (vec![0, 1, 2, 3, 4], vec![4, 3, 2, 1, 0]),
        ];
        Request::new(id, "7b-sim", "fp16", CotMode::SlowThink, ex)
    };
    let prompt_tokens = fp16_request(0).prompt_tokens_hint();
    // Request i is admitted with 3-i slow_think requests still queued
    // behind it (FIFO within one mode), so its budget prices exactly the
    // wait the admission-time decision will see — through the same public
    // pricing functions `decide` uses, making the f64 comparison exact.
    let budget = |queued_ahead: usize| -> f64 {
        let snap = SloSnapshot {
            prompt_tokens,
            queued_by_mode: [0, 0, queued_ahead],
            headroom: None,
            grow_horizon: horizon,
        };
        let wait = SloPolicy::queue_wait_ms(&cost, arrival.0, &snap);
        let cheapest = SloPolicy::default()
            .candidates(arrival)
            .into_iter()
            .map(|(p, m)| SloPolicy::service_ms(&cost, p, m, &snap))
            .fold(f64::INFINITY, f64::min);
        wait + cheapest
    };
    // The gate is genuinely tight: the arrival pair alone busts the budget.
    let unloaded = SloSnapshot::unloaded(prompt_tokens, horizon);
    let fp16_ms = SloPolicy::service_ms(&cost, arrival.0, arrival.1, &unloaded);
    assert!(
        budget(0) < fp16_ms,
        "budget {:.1} ms must undercut FP16 slow_think at {:.1} ms",
        budget(0),
        fp16_ms
    );

    let run = |policy: SloPolicy| {
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 40);
        let mut be = MockBackend::new(64, 48, 96, script);
        let cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous)
            .with_kv(KvConfig::paged(16, 16 * 16))
            .with_cost(Arc::new(cost))
            .with_slo(policy);
        let reqs: Vec<Request> =
            (0..4u64).map(|i| fp16_request(i).with_slo_ms(budget(3 - i as usize))).collect();
        let (resps, report) =
            Scheduler::new(&tk, cfg).run_batch(&mut be, &reqs).expect("session");
        assert_eq!(resps.len(), 4, "every request answered");
        (resps, report)
    };

    let (pinned_resps, pinned) = run(SloPolicy::pinned());
    assert_eq!(pinned.slo_misses_modeled, 4, "pinned FP16 slow_think misses 4/4");
    assert_eq!(pinned.slo_downgrades_mode, 0, "pinned never moves a request");
    assert_eq!(pinned.slo_downgrades_precision, 0);
    assert!(
        pinned_resps.iter().any(|r| r.truncated),
        "running the un-degraded traces must starve the 16-page pool"
    );

    let (adaptive_resps, adaptive) = run(SloPolicy::default());
    assert!(
        adaptive.slo_misses_modeled <= 1,
        "policy must meet modeled deadlines on >= 3/4 (missed {})",
        adaptive.slo_misses_modeled
    );
    assert_eq!(adaptive.slo_misses_modeled, 0, "fully satisfiable by degrading");
    assert_eq!(adaptive.slo_downgrades_mode, 4, "every budget forces the short mode");
    assert_eq!(adaptive.slo_downgrades_precision, 4, "and the fast precision");
    for r in &adaptive_resps {
        assert!(!r.truncated, "request {} truncated under the SLO policy", r.id);
        assert!(!r.tokens.is_empty(), "request {} got no tokens", r.id);
    }
}

/// The ISSUE 8 identity pin, through the FULL server loop: a configured
/// [`SloPolicy`] with no request carrying a budget is byte-identical to a
/// policy-free server — same tokens, same truncation flags — and every
/// `slo_*` metric stays zero on both sides.
#[test]
fn slo_policy_without_budgets_is_byte_identical_through_the_server() -> Result<()> {
    let run = |with_policy: bool| -> Result<(Vec<(u64, Vec<u32>, bool)>, [u64; 3])> {
        let tk = Tokenizer::minilang_default();
        let mut cfg = SchedulerConfig::fixed(2, AdmitGate::Continuous)
            .with_kv(KvConfig::paged(16, 16 * 16))
            .with_cost(Arc::new(
                AtlasCostModel::openpangu_7b()
                    .with_token_inflation(TokenInflation::a2_calibrated()),
            ));
        if with_policy {
            cfg = cfg.with_slo(SloPolicy::default());
        }
        let (mut server, handle) = Server::new(
            mock_provider(&tk, 16),
            &tk,
            cfg,
            AdmitConfig::with_wait(false, Duration::from_millis(50)),
        );
        let rxs: Vec<_> = [
            request(0, CotMode::SlowThink),
            request(1, CotMode::NoThink),
            request(2, CotMode::AutoThink),
            request(3, CotMode::NoThink),
        ]
        .into_iter()
        .map(|r| handle.submit(r).unwrap())
        .collect();
        drop(handle);
        let processed = server.run_until_idle(Duration::from_millis(200))?;
        assert_eq!(processed, 4);
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv().map(|r| (r.id, r.tokens, r.truncated)))
            .collect::<Result<Vec<_>, _>>()?;
        let slo = [
            server.metrics.counter("slo_downgrades_mode"),
            server.metrics.counter("slo_downgrades_precision"),
            server.metrics.counter("slo_misses_modeled"),
        ];
        Ok((out, slo))
    };
    let (base, base_slo) = run(false)?;
    let (gated, gated_slo) = run(true)?;
    assert_eq!(base, gated, "unconstrained requests must be byte-identical");
    assert_eq!(base_slo, [0; 3], "no policy, no slo accounting");
    assert_eq!(gated_slo, [0; 3], "a policy without budgets never fires");
    Ok(())
}

/// Inflation-adjusted headroom steering (ISSUE 8, fleet variant). Two
/// devices differ only in pool size (3 pages vs 16); a W4A8 slow_think
/// request expects ceil(96 x 1.24) = 120 decode tokens under a2-calibrated
/// inflation, so its estimated footprint (2 prompt + 2 excess pages)
/// overflows the small card that its FP16-length estimate (2 pages) would
/// fit:
///
///   * the inflation-honest fleet routes it to the big card and serves the
///     full 40-token trace untruncated;
///   * the identity-priced fleet parks it on the small card (index tie
///     break among fitting devices) and truncates mid-trace — the modeled
///     gap made visible;
///   * an unmeetably budgeted sibling exercises the `slo_*` counters
///     through the per-device reports, the fleet rollup, and rendering.
#[test]
fn fleet_router_respects_inflation_adjusted_headroom() {
    use pangu_atlas_quant::coordinator::fleet::{
        Fleet, FleetConfig, LeastLoadedRouter, RebalanceConfig,
    };
    let tk = Tokenizer::minilang_default();
    let w4a8_request = |id: u64, mode: CotMode| {
        let ex = vec![
            (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]),
            (vec![0, 1, 2, 3, 4], vec![4, 3, 2, 1, 0]),
        ];
        Request::new(id, "7b-sim", "w4a8", mode, ex)
    };
    let requests = vec![
        w4a8_request(0, CotMode::SlowThink),
        w4a8_request(1, CotMode::NoThink),
        // Budget 0 is unmeetable; W4A8 is the ladder tail, so the policy
        // records one mode downgrade and one modeled miss fleet-wide.
        w4a8_request(2, CotMode::SlowThink).with_slo_ms(0.0),
    ];
    let run = |inflation: TokenInflation| {
        let device = |pages: usize| {
            SchedulerConfig::fixed(2, AdmitGate::Continuous)
                .with_kv(KvConfig::paged(16, pages * 16))
                .with_cost(Arc::new(
                    AtlasCostModel::openpangu_7b().with_token_inflation(inflation),
                ))
                .with_slo(SloPolicy::default())
        };
        let cfg = FleetConfig {
            devices: vec![device(3), device(16)],
            admit: AdmitConfig::with_wait(false, Duration::ZERO),
            rebalance: RebalanceConfig::default(),
        };
        let mut fleet =
            Fleet::new(&tk, cfg, Box::new(LeastLoadedRouter::new())).expect("fleet");
        let mut providers = vec![mock_provider(&tk, 40), mock_provider(&tk, 40)];
        let (resps, report) =
            fleet.run_batch(&mut providers, &requests).expect("fleet batch");
        assert_eq!(resps.len(), 3, "every request answered exactly once");
        (resps, report)
    };

    let (honest_resps, honest) = run(TokenInflation::a2_calibrated());
    // The fat request landed on the big card: full trace, no truncation,
    // and the small card served only the 3-token no_think.
    assert_eq!(honest_resps[0].tokens.len(), 40, "slow_think served in full");
    for r in &honest_resps {
        assert!(!r.truncated, "request {} truncated despite honest routing", r.id);
    }
    assert_eq!(honest.devices[0].report.completed, 1);
    assert_eq!(honest.devices[1].report.completed, 2);
    assert_eq!(honest.devices[0].report.tokens_generated, 3);
    // SLO accounting flows through the per-device reports into the fleet
    // rollup and its rendering.
    assert_eq!(honest.rollup().slo_downgrades_mode, 1);
    assert_eq!(honest.rollup().slo_downgrades_precision, 0);
    assert_eq!(honest.rollup().slo_misses_modeled, 1);
    let rendered = honest.render();
    assert!(rendered.contains("slo_downgrades=1/0"), "render: {rendered}");
    assert!(rendered.contains("slo_misses=1"), "render: {rendered}");
    assert_eq!(
        honest.rollup().kv_pages_allocated,
        honest.rollup().kv_pages_released,
        "fleet-wide page conservation"
    );

    // Identity pricing estimates the FP16-length trace, routes the fat
    // request onto the small card, and pays with a mid-trace truncation.
    let (naive_resps, naive) = run(TokenInflation::IDENTITY);
    assert!(
        naive_resps[0].truncated,
        "identity-priced placement must starve the small pool"
    );
    assert!(naive_resps[0].tokens.len() < 40, "the trace was cut short");
    assert!(
        naive.devices[0].report.tokens_generated > 3,
        "the fat request ran on device 0"
    );
}

// ---------------------------------------------------------------------------
// Artifact-backed test (skips when artifacts are absent)
// ---------------------------------------------------------------------------

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn serve_mixed_modes_through_channel_server() -> Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let rt = Runtime::open(&dir)?;
    let tk = Tokenizer::from_manifest(&rt.manifest.raw)?;
    let bench = Benchmark::load(&dir.join(&rt.manifest.datasets["mbpp_s"]))?;
    // Serve over the manifest's full compiled bucket ladder so the device
    // backend's migrate path is exercised end-to-end when artifacts exist.
    let mut buckets = rt.manifest.serve_buckets.clone();
    if buckets.is_empty() {
        buckets = vec![8];
    }
    let (mut server, handle) = Server::new(
        pangu_atlas_quant::runtime::backend::DeviceProvider::new(rt),
        &tk,
        SchedulerConfig::ladder(buckets, AdmitGate::Continuous)?
            .with_cost(Arc::new(AtlasCostModel::openpangu_7b())),
        AdmitConfig::with_wait(true, Duration::from_millis(5)),
    );

    let tasks: Vec<_> = bench.tasks.iter().take(12).cloned().collect();
    let tasks2 = tasks.clone();
    let client = std::thread::spawn(move || {
        let mut rxs = Vec::new();
        for (i, task) in tasks2.iter().enumerate() {
            let mode = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink][i % 3];
            let req = Request::new(i as u64, "7b-sim", "int8", mode, task.examples.clone());
            rxs.push(handle.submit(req).unwrap());
        }
        rxs.into_iter().map(|rx| rx.recv().unwrap()).collect::<Vec<_>>()
    });

    let processed = server.run_until_idle(Duration::from_millis(300))?;
    let responses = client.join().unwrap();

    assert_eq!(processed, 12);
    assert_eq!(responses.len(), 12);
    // Replies are keyed by id, so each receiver holds its own response no
    // matter how admission reordered the queue.
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "reply delivered to the wrong caller");
        assert!(!r.tokens.is_empty(), "empty generation for request {i}");
        assert!(r.latency_ms >= 0.0);
        assert!(r.ttft_ms <= r.latency_ms);
    }
    // The stack must produce *some* scoreable outputs (format learned).
    let wellformed = responses
        .iter()
        .zip(&tasks)
        .filter(|(r, t)| {
            !matches!(
                scoring::score_generation(&tk, t, &r.tokens),
                scoring::Outcome::Malformed
            )
        })
        .count();
    assert!(
        wellformed >= 6,
        "only {wellformed}/12 generations were well-formed"
    );
    assert!(server.metrics.counter("sessions") >= 1);
    assert!(server.metrics.counter("decode_steps") > 0);
    Ok(())
}
