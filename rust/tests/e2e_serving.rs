//! End-to-end serving test: full stack (channel server -> batcher ->
//! engine -> PJRT runtime) over real artifacts with concurrent clients.
//! Skips when artifacts are absent.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;
use pangu_atlas_quant::bench_suite::dataset::Benchmark;
use pangu_atlas_quant::bench_suite::scoring;
use pangu_atlas_quant::coordinator::batcher::BatcherConfig;
use pangu_atlas_quant::coordinator::request::Request;
use pangu_atlas_quant::coordinator::server::Server;
use pangu_atlas_quant::runtime::Runtime;
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn serve_mixed_modes_through_channel_server() -> Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let rt = Runtime::open(&dir)?;
    let tk = Tokenizer::from_manifest(&rt.manifest.raw)?;
    let bench = Benchmark::load(&dir.join(&rt.manifest.datasets["mbpp_s"]))?;
    let buckets = rt.manifest.serve_buckets.clone();
    let (mut server, handle) = Server::new(
        rt,
        &tk,
        BatcherConfig { buckets, max_wait: Duration::from_millis(5) },
    );

    let tasks: Vec<_> = bench.tasks.iter().take(12).cloned().collect();
    let tasks2 = tasks.clone();
    let client = std::thread::spawn(move || {
        let mut rxs = Vec::new();
        for (i, task) in tasks2.iter().enumerate() {
            let mode = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink][i % 3];
            let req = Request::new(i as u64, "7b-sim", "int8", mode, task.examples.clone());
            rxs.push(handle.submit(req).unwrap());
        }
        rxs.into_iter().map(|rx| rx.recv().unwrap()).collect::<Vec<_>>()
    });

    let processed = server.run_until_idle(Duration::from_millis(300))?;
    let responses = client.join().unwrap();

    assert_eq!(processed, 12);
    assert_eq!(responses.len(), 12);
    // Responses arrive in request order per client (FIFO batching).
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "response order broken");
        assert!(!r.tokens.is_empty(), "empty generation for request {i}");
        assert!(r.latency_ms >= 0.0);
    }
    // The stack must produce *some* scoreable outputs (format learned).
    let wellformed = responses
        .iter()
        .zip(&tasks)
        .filter(|(r, t)| {
            !matches!(
                scoring::score_generation(&tk, t, &r.tokens),
                scoring::Outcome::Malformed
            )
        })
        .count();
    assert!(
        wellformed >= 6,
        "only {wellformed}/12 generations were well-formed"
    );
    assert!(server.metrics.counter("waves") >= 2);
    Ok(())
}
