//! Property tests (propcheck) over the analytical Atlas A2 models — the
//! structural invariants behind the paper's Table 3, which the scheduler's
//! cost-model ladder now depends on:
//!
//!   * prefill and decode latency are monotone (non-decreasing) in batch;
//!   * a quantized variant's total latency never exceeds FP16's at equal
//!     batch (the speedup is >= 1 everywhere, not just at the published
//!     endpoints);
//!   * the FP16 - INT8 memory delta is batch-independent (45.31 - 39.01 =
//!     16.84 - 10.55 ~= 6.3 GB in the paper: exactly the weight-precision
//!     delta).

use pangu_atlas_quant::atlas::{memory_model, perf_model, AtlasSpec, ModelDims};
use pangu_atlas_quant::quant::Precision;
use pangu_atlas_quant::util::propcheck::{check, ensure};

fn dims_for(tag: u8) -> ModelDims {
    if tag == 0 {
        ModelDims::openpangu_1b()
    } else {
        ModelDims::openpangu_7b()
    }
}

fn precision_for(tag: usize) -> Precision {
    Precision::ALL[tag % Precision::ALL.len()]
}

#[test]
fn prop_prefill_latency_monotone_in_batch() {
    check(
        "prefill-monotone-in-batch",
        200,
        0xA71A5,
        |rng| {
            let b1 = rng.range(1, 64);
            let b2 = rng.range(1, 64);
            (rng.range(0, 1) as u8, rng.range(0, 8), b1.min(b2), b1.max(b2))
        },
        |&(dims_tag, p_tag, lo, hi)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let t_lo = perf_model::prefill_latency(&spec, &dims, p, lo).total_ms();
            let t_hi = perf_model::prefill_latency(&spec, &dims, p, hi).total_ms();
            ensure(
                t_lo <= t_hi + 1e-9,
                format!("{p}: prefill({lo}) = {t_lo} > prefill({hi}) = {t_hi}"),
            )
        },
    );
}

#[test]
fn prop_decode_latency_monotone_in_batch() {
    check(
        "decode-monotone-in-batch",
        200,
        0xA71B6,
        |rng| {
            let b1 = rng.range(1, 64);
            let b2 = rng.range(1, 64);
            (rng.range(0, 1) as u8, rng.range(0, 8), b1.min(b2), b1.max(b2))
        },
        |&(dims_tag, p_tag, lo, hi)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let t_lo = perf_model::decode_latency(&spec, &dims, p, lo).total_ms();
            let t_hi = perf_model::decode_latency(&spec, &dims, p, hi).total_ms();
            ensure(
                t_lo <= t_hi + 1e-9,
                format!("{p}: decode({lo}) = {t_lo} > decode({hi}) = {t_hi}"),
            )
        },
    );
}

#[test]
fn prop_quantized_total_never_exceeds_fp16_at_equal_batch() {
    check(
        "quantized-not-slower-than-fp16",
        200,
        0xA71C7,
        |rng| (rng.range(0, 1) as u8, rng.range(0, 8), rng.range(1, 64)),
        |&(dims_tag, p_tag, batch)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let fp_pre = perf_model::prefill_latency(&spec, &dims, Precision::Fp16, batch);
            let q_pre = perf_model::prefill_latency(&spec, &dims, p, batch);
            ensure(
                q_pre.total_ms() <= fp_pre.total_ms() + 1e-9,
                format!(
                    "{p}: prefill@{batch} {} > fp16 {}",
                    q_pre.total_ms(),
                    fp_pre.total_ms()
                ),
            )?;
            let fp_dec = perf_model::decode_latency(&spec, &dims, Precision::Fp16, batch);
            let q_dec = perf_model::decode_latency(&spec, &dims, p, batch);
            ensure(
                q_dec.total_ms() <= fp_dec.total_ms() + 1e-9,
                format!(
                    "{p}: decode@{batch} {} > fp16 {}",
                    q_dec.total_ms(),
                    fp_dec.total_ms()
                ),
            )
        },
    );
}

#[test]
fn prop_memory_delta_batch_independent() {
    // The Table 3 structural invariant: only the weight term depends on
    // precision, so the FP16-vs-quantized total delta is the same at every
    // batch size — and equals the weight-precision delta.
    check(
        "memory-delta-batch-independent",
        200,
        0xA71D8,
        |rng| {
            (
                rng.range(0, 1) as u8,
                rng.range(0, 8),
                rng.range(1, 64),
                rng.range(1, 64),
            )
        },
        |&(dims_tag, p_tag, b1, b2)| {
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let delta_at = |b: usize| {
                memory_model::prefill_memory(&dims, Precision::Fp16, b).total_gib()
                    - memory_model::prefill_memory(&dims, p, b).total_gib()
            };
            let d1 = delta_at(b1);
            let d2 = delta_at(b2);
            ensure(
                (d1 - d2).abs() < 1e-6,
                format!("{p}: delta({b1}) = {d1} != delta({b2}) = {d2}"),
            )?;
            // The delta is exactly the weight-precision delta.
            const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
            let want = dims.params
                * (Precision::Fp16.weight_bytes_per_param() - p.weight_bytes_per_param())
                / GIB;
            ensure(
                (d1 - want).abs() < 1e-6,
                format!("{p}: delta {d1} != weight delta {want}"),
            )
        },
    );
}
