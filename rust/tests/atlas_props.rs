//! Property tests (propcheck) over the analytical Atlas A2 models — the
//! structural invariants behind the paper's Table 3, which the scheduler's
//! cost-model ladder now depends on:
//!
//!   * prefill and decode latency are monotone (non-decreasing) in batch;
//!   * a quantized variant's total latency never exceeds FP16's at equal
//!     batch (the speedup is >= 1 everywhere, not just at the published
//!     endpoints);
//!   * the FP16 - INT8 memory delta is batch-independent (45.31 - 39.01 =
//!     16.84 - 10.55 ~= 6.3 GB in the paper: exactly the weight-precision
//!     delta).

use pangu_atlas_quant::atlas::memory_model::KvPrecision;
use pangu_atlas_quant::atlas::{memory_model, perf_model, AtlasSpec, ModelDims};
use pangu_atlas_quant::quant::Precision;
use pangu_atlas_quant::util::propcheck::{check, ensure};

fn dims_for(tag: u8) -> ModelDims {
    if tag == 0 {
        ModelDims::openpangu_1b()
    } else {
        ModelDims::openpangu_7b()
    }
}

fn precision_for(tag: usize) -> Precision {
    Precision::ALL[tag % Precision::ALL.len()]
}

#[test]
fn prop_prefill_latency_monotone_in_batch() {
    check(
        "prefill-monotone-in-batch",
        200,
        0xA71A5,
        |rng| {
            let b1 = rng.range(1, 64);
            let b2 = rng.range(1, 64);
            (rng.range(0, 1) as u8, rng.range(0, 8), b1.min(b2), b1.max(b2))
        },
        |&(dims_tag, p_tag, lo, hi)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let t_lo = perf_model::prefill_latency(&spec, &dims, p, lo).total_ms();
            let t_hi = perf_model::prefill_latency(&spec, &dims, p, hi).total_ms();
            ensure(
                t_lo <= t_hi + 1e-9,
                format!("{p}: prefill({lo}) = {t_lo} > prefill({hi}) = {t_hi}"),
            )
        },
    );
}

#[test]
fn prop_decode_latency_monotone_in_batch() {
    check(
        "decode-monotone-in-batch",
        200,
        0xA71B6,
        |rng| {
            let b1 = rng.range(1, 64);
            let b2 = rng.range(1, 64);
            (rng.range(0, 1) as u8, rng.range(0, 8), b1.min(b2), b1.max(b2))
        },
        |&(dims_tag, p_tag, lo, hi)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let t_lo = perf_model::decode_latency(&spec, &dims, p, lo).total_ms();
            let t_hi = perf_model::decode_latency(&spec, &dims, p, hi).total_ms();
            ensure(
                t_lo <= t_hi + 1e-9,
                format!("{p}: decode({lo}) = {t_lo} > decode({hi}) = {t_hi}"),
            )
        },
    );
}

#[test]
fn prop_quantized_total_never_exceeds_fp16_at_equal_batch() {
    check(
        "quantized-not-slower-than-fp16",
        200,
        0xA71C7,
        |rng| (rng.range(0, 1) as u8, rng.range(0, 8), rng.range(1, 64)),
        |&(dims_tag, p_tag, batch)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let fp_pre = perf_model::prefill_latency(&spec, &dims, Precision::Fp16, batch);
            let q_pre = perf_model::prefill_latency(&spec, &dims, p, batch);
            ensure(
                q_pre.total_ms() <= fp_pre.total_ms() + 1e-9,
                format!(
                    "{p}: prefill@{batch} {} > fp16 {}",
                    q_pre.total_ms(),
                    fp_pre.total_ms()
                ),
            )?;
            let fp_dec = perf_model::decode_latency(&spec, &dims, Precision::Fp16, batch);
            let q_dec = perf_model::decode_latency(&spec, &dims, p, batch);
            ensure(
                q_dec.total_ms() <= fp_dec.total_ms() + 1e-9,
                format!(
                    "{p}: decode@{batch} {} > fp16 {}",
                    q_dec.total_ms(),
                    fp_dec.total_ms()
                ),
            )
        },
    );
}

#[test]
fn prop_memory_delta_batch_independent() {
    // The Table 3 structural invariant: only the weight term depends on
    // precision, so the FP16-vs-quantized total delta is the same at every
    // batch size — and equals the weight-precision delta.
    check(
        "memory-delta-batch-independent",
        200,
        0xA71D8,
        |rng| {
            (
                rng.range(0, 1) as u8,
                rng.range(0, 8),
                rng.range(1, 64),
                rng.range(1, 64),
            )
        },
        |&(dims_tag, p_tag, b1, b2)| {
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let delta_at = |b: usize| {
                memory_model::prefill_memory(&dims, Precision::Fp16, b).total_gib()
                    - memory_model::prefill_memory(&dims, p, b).total_gib()
            };
            let d1 = delta_at(b1);
            let d2 = delta_at(b2);
            ensure(
                (d1 - d2).abs() < 1e-6,
                format!("{p}: delta({b1}) = {d1} != delta({b2}) = {d2}"),
            )?;
            // The delta is exactly the weight-precision delta.
            const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
            let want = dims.params
                * (Precision::Fp16.weight_bytes_per_param() - p.weight_bytes_per_param())
                / GIB;
            ensure(
                (d1 - want).abs() < 1e-6,
                format!("{p}: delta {d1} != weight delta {want}"),
            )
        },
    );
}

#[test]
fn prop_int8_kv_halves_kv_and_never_hurts() {
    // KV precision is an independent axis: INT8 KV halves exactly the KV
    // term at every (weight precision, batch), so totals are strictly
    // smaller, savings strictly bigger, and the worst-case fit predicate
    // monotone (everything FP16-KV fits, INT8-KV fits too).
    check(
        "int8-kv-halves-kv-term",
        200,
        0xA71E9,
        |rng| (rng.range(0, 1) as u8, rng.range(0, 8), rng.range(1, 64)),
        |&(dims_tag, p_tag, batch)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let fp = memory_model::prefill_memory_kv(&dims, p, KvPrecision::Fp16, batch);
            let q = memory_model::prefill_memory_kv(&dims, p, KvPrecision::Int8, batch);
            ensure(
                (q.kv_gib - fp.kv_gib / 2.0).abs() < 1e-9,
                format!("{p}@{batch}: int8 kv {} != half of {}", q.kv_gib, fp.kv_gib),
            )?;
            ensure(
                (fp.total_gib() - q.total_gib() - fp.kv_gib / 2.0).abs() < 1e-9,
                "total delta must be exactly the halved KV term",
            )?;
            ensure(
                memory_model::savings_pct_kv(&dims, p, KvPrecision::Int8, batch)
                    >= memory_model::savings_pct_kv(&dims, p, KvPrecision::Fp16, batch),
                "int8-kv savings must dominate",
            )?;
            if memory_model::fits_kv(&spec, &dims, p, KvPrecision::Fp16, batch) {
                ensure(
                    memory_model::fits_kv(&spec, &dims, p, KvPrecision::Int8, batch),
                    "int8 kv must fit wherever fp16 kv fits",
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_pool_budget_consistent_with_live_fit() {
    // The paged pool's budget is the largest KV-token load the live-fit
    // predicate accepts: budget tokens fit, budget + one page does not
    // (modulo the sub-token float remainder), and the budget shrinks as
    // the serving batch's activation workspace grows.
    check(
        "kv-pool-budget-live-fit",
        100,
        0xA71FA,
        |rng| {
            (
                rng.range(0, 1) as u8,
                rng.range(0, 8),
                rng.range(1, 32),
                if rng.chance(0.5) { KvPrecision::Fp16 } else { KvPrecision::Int8 },
            )
        },
        |&(dims_tag, p_tag, batch, kv)| {
            let spec = AtlasSpec::default();
            let dims = dims_for(dims_tag);
            let p = precision_for(p_tag);
            let budget = memory_model::kv_pool_budget_tokens(&spec, &dims, p, kv, batch);
            ensure(budget > 0, "default card must leave KV headroom")?;
            ensure(
                memory_model::fits_live(&spec, &dims, p, kv, batch, budget),
                "the pool budget itself must fit",
            )?;
            ensure(
                !memory_model::fits_live(&spec, &dims, p, kv, batch, budget + 64),
                "a page past the budget must not fit",
            )?;
            let bigger_batch = memory_model::kv_pool_budget_tokens(&spec, &dims, p, kv, batch + 8);
            ensure(
                bigger_batch <= budget,
                format!("budget grew with batch: {bigger_batch} > {budget}"),
            )
        },
    );
}
