//! Property tests (propcheck) over the streaming delivery path: the
//! token-sink hook in the scheduler's decode loop, the per-client flush
//! ladder in `coordinator::stream`, and the server wiring around both.
//!
//! The two load-bearing invariants (ISSUE: streaming front end):
//!
//!   * **Byte identity** — the token stream a sink observes is exactly the
//!     sequence of freshly *sampled* tokens, so per request it equals the
//!     final `Response::tokens` byte-for-byte, even under tight paged
//!     pools with preempt-and-recompute (replayed prefixes are restored,
//!     never re-sampled, so the sink sees each token exactly once).
//!
//!   * **No head-of-line blocking** — a stalled streaming consumer (full
//!     chunk channel, never read) degrades its own flush granularity and
//!     must not change one byte or one schedule step for anybody else.

use std::collections::BTreeMap;
use std::time::Duration;

use pangu_atlas_quant::coordinator::admission::{AdmissionQueue, AdmitConfig};
use pangu_atlas_quant::coordinator::kv::KvConfig;
use pangu_atlas_quant::coordinator::request::Request;
use pangu_atlas_quant::coordinator::scheduler::{
    AdmitGate, PreemptConfig, Scheduler, SchedulerConfig,
};
use pangu_atlas_quant::coordinator::server::Server;
use pangu_atlas_quant::coordinator::stream::TokenSink;
use pangu_atlas_quant::runtime::backend::{minilang_mock_script, MockBackend, MockProvider};
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};
use pangu_atlas_quant::util::propcheck::{check, ensure, ensure_eq};

const MODES: [CotMode; 3] = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];

fn mk_request(id: u64, mode_tag: u8, examples: u8) -> Request {
    let ex: Vec<(Vec<u8>, Vec<u8>)> = (0..examples)
        .map(|_| (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]))
        .collect();
    Request::new(id, "7b-sim", "int8", MODES[mode_tag as usize], ex)
}

/// A sink that records every token it is handed, per request id, plus
/// whether the decode-step stamps it saw were monotone non-decreasing.
#[derive(Default)]
struct CollectSink {
    per_id: BTreeMap<u64, Vec<u32>>,
    last_step: usize,
    monotone: bool,
    started: bool,
}

impl TokenSink for CollectSink {
    fn on_token(&mut self, id: u64, token: u32, decode_step: usize) {
        if self.started && decode_step < self.last_step {
            self.monotone = false;
        }
        self.started = true;
        self.last_step = decode_step;
        self.per_id.entry(id).or_default().push(token);
    }
}

// ---------------------------------------------------------------------------
// Byte identity at the scheduler layer, including preempt-and-recompute
// ---------------------------------------------------------------------------

/// Randomized workloads through `Scheduler::run_streaming` with a
/// collecting sink, over both an ample pool and a tight paged pool with
/// preemption enabled: the per-request stream the sink observed equals
/// `Response::tokens` exactly (no token missed, duplicated, or reordered),
/// and the decode-step stamps never go backwards. The tight runs must
/// actually preempt across the suite, or the replay half of the property
/// would be vacuous.
#[test]
fn prop_sink_stream_is_byte_identical_under_preemption() {
    let run = |kv_cfg: Option<KvConfig>,
               bucket: usize,
               shapes: &[(u8, u8)]|
     -> Result<(BTreeMap<u64, Vec<u32>>, BTreeMap<u64, Vec<u32>>, usize), String> {
        let tk = Tokenizer::minilang_default();
        let script = minilang_mock_script(&tk, 30);
        let mut be = MockBackend::new(64, 48, 96, script);
        let mut cfg = SchedulerConfig::fixed(bucket, AdmitGate::Continuous).with_preempt(
            PreemptConfig { enabled: true, max_per_seq: 64, restore_headroom_pages: 1 },
        );
        if let Some(kv_cfg) = kv_cfg {
            cfg = cfg.with_kv(kv_cfg);
        }
        let sched = Scheduler::new(&tk, cfg);
        let mut queue = AdmissionQueue::new(AdmitConfig::with_wait(false, Duration::ZERO));
        for (i, &(tag, examples)) in shapes.iter().enumerate() {
            queue.push(mk_request(i as u64, tag, examples));
        }
        let mut sink = CollectSink { monotone: true, ..CollectSink::default() };
        let mut responses: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        let report = sched
            .run_streaming(
                &mut be,
                &mut queue,
                &mut |_| {},
                &mut |r| {
                    responses.insert(r.id, r.tokens);
                },
                &mut sink,
            )
            .map_err(|e| e.to_string())?;
        ensure(sink.monotone, "sink saw decode_step go backwards")?;
        Ok((sink.per_id, responses, report.preemptions))
    };
    let total_preemptions = std::cell::Cell::new(0usize);
    check(
        "stream-sink-byte-identity",
        25,
        0x57B1,
        |rng| {
            let bucket = rng.range(2, 4);
            let shapes: Vec<(u8, u8)> = (0..rng.range(2, 6))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8))
                .collect();
            // 5..=8 pages: tight enough to starve a 4-page-peak sequence,
            // never too tight to restore it (mirrors the preempt suite).
            let pages = rng.range(5, 8);
            (bucket, shapes, pages)
        },
        |(bucket, shapes, pages)| {
            let (streamed, responses, _) = run(None, *bucket, shapes)?;
            ensure_eq(responses.len(), shapes.len(), "ample: every request answered")?;
            ensure(
                streamed == responses,
                "ample: sink stream diverged from the final responses",
            )?;
            let (streamed, responses, preemptions) =
                run(Some(KvConfig::paged(16, pages * 16)), *bucket, shapes)?;
            total_preemptions.set(total_preemptions.get() + preemptions);
            ensure_eq(responses.len(), shapes.len(), "tight: every request answered")?;
            ensure(
                streamed == responses,
                "tight: a preemption replayed tokens into the sink (or dropped them)",
            )?;
            Ok(())
        },
    );
    assert!(
        total_preemptions.get() > 0,
        "the generator never starved a pool: the replay property was vacuous"
    );
}

// ---------------------------------------------------------------------------
// Byte identity at the server layer: chunks concatenate to the response
// ---------------------------------------------------------------------------

/// Randomized mixed workloads (streaming and plain submissions
/// interleaved) through the server: for every streaming client with an
/// ample chunk channel, the concatenated chunks equal the final
/// `Response::tokens`, chunk stamps are strictly increasing per client,
/// nothing degrades and no tail is dropped; plain submissions are
/// unaffected and still answered.
#[test]
fn prop_streamed_chunks_concat_to_the_response() {
    check(
        "stream-chunks-concat",
        25,
        0x57B2,
        |rng| {
            let bucket = rng.range(1, 4);
            let shapes: Vec<(u8, u8, bool)> = (0..rng.range(1, 6))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8, rng.chance(0.7)))
                .collect();
            (bucket, shapes)
        },
        |(bucket, shapes)| {
            let tk = Tokenizer::minilang_default();
            let script = minilang_mock_script(&tk, 30);
            let provider = MockProvider::new(MockBackend::new(64, 48, 96, script));
            let (mut server, handle) = Server::new(
                provider,
                &tk,
                SchedulerConfig::fixed(*bucket, AdmitGate::Continuous),
                AdmitConfig::with_wait(false, Duration::ZERO),
            );
            let mut streams = Vec::new();
            let mut plain = Vec::new();
            for (i, &(tag, examples, stream)) in shapes.iter().enumerate() {
                let req = mk_request(i as u64, tag, examples);
                if stream {
                    streams.push(handle.submit_streaming(req, 4096).map_err(|e| e.to_string())?);
                } else {
                    plain.push(handle.submit(req).map_err(|e| e.to_string())?);
                }
            }
            drop(handle);
            server
                .run_until_idle(Duration::from_millis(10))
                .map_err(|e| e.to_string())?;
            let mut streamed_tokens = 0u64;
            for s in streams {
                let (chunks, resp) = s.collect().map_err(|e| e.to_string())?;
                let concat: Vec<u32> =
                    chunks.iter().flat_map(|c| c.tokens.iter().copied()).collect();
                ensure(
                    concat == resp.tokens,
                    format!("request {}: chunks do not concat to the response", resp.id),
                )?;
                ensure(
                    chunks.iter().all(|c| !c.tokens.is_empty()),
                    "an empty chunk was flushed",
                )?;
                ensure(
                    chunks.windows(2).all(|w| w[0].decode_step < w[1].decode_step),
                    "chunk decode_step stamps must strictly increase per client",
                )?;
                streamed_tokens += resp.tokens.len() as u64;
            }
            for rx in plain {
                let resp = rx.recv().map_err(|e| e.to_string())?;
                ensure(!resp.tokens.is_empty(), "plain submission got tokens")?;
            }
            let m = &server.metrics;
            ensure_eq(m.counter("stream_tokens"), streamed_tokens, "every token streamed")?;
            ensure_eq(m.counter("stream_degraded_to_chunk"), 0, "ample channel: no degrade")?;
            ensure_eq(m.counter("stream_degraded_to_final"), 0, "ample channel: no degrade")?;
            ensure_eq(m.counter("stream_tail_dropped"), 0, "ample channel: no tail drop")?;
            ensure_eq(m.counter("replies_dropped"), 0, "all receivers were held")?;
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// No head-of-line blocking: a stalled consumer affects only itself
// ---------------------------------------------------------------------------

/// Deterministic A/B runs of identical workloads: run A submits everything
/// as plain requests; run B resubmits the same workload with request 0 as
/// a streaming client on a capacity-1 channel that is never read (a fully
/// stalled consumer). Every *other* request's tokens and schedule position
/// (`first_token_step`) must be identical between the runs, and the total
/// decode-step count must match — the stalled client cost nobody anything.
/// The stalled client itself must have degraded (non-vacuity) and its
/// streamed prefix must still be a prefix of its final response.
#[test]
fn prop_stalled_consumer_never_blocks_other_requests() {
    // (per-request tokens + first_token_step, decode_steps, degraded count)
    type RunOut = (BTreeMap<u64, (Vec<u32>, usize)>, u64, u64);
    let run = |stall: bool, bucket: usize, shapes: &[(u8, u8)]| -> Result<RunOut, String> {
        let tk = Tokenizer::minilang_default();
        let script = minilang_mock_script(&tk, 30);
        let provider = MockProvider::new(MockBackend::new(64, 48, 96, script));
        let (mut server, handle) = Server::new(
            provider,
            &tk,
            SchedulerConfig::fixed(bucket, AdmitGate::Continuous),
            AdmitConfig::with_wait(false, Duration::ZERO),
        );
        // Request 0 is always a slow_think anchor so the stalled variant
        // has a long stream to (fail to) deliver.
        let mut stalled = None;
        if stall {
            let s = handle.submit_streaming(mk_request(0, 2, 1), 1).map_err(|e| e.to_string())?;
            stalled = Some(s);
        }
        let mut plain = Vec::new();
        if !stall {
            plain.push((0u64, handle.submit(mk_request(0, 2, 1)).map_err(|e| e.to_string())?));
        }
        for (i, &(tag, examples)) in shapes.iter().enumerate() {
            let id = i as u64 + 1;
            let rx = handle.submit(mk_request(id, tag, examples)).map_err(|e| e.to_string())?;
            plain.push((id, rx));
        }
        drop(handle);
        server
            .run_until_idle(Duration::from_millis(10))
            .map_err(|e| e.to_string())?;
        let mut out = BTreeMap::new();
        for (id, rx) in plain {
            let resp = rx.recv().map_err(|e| e.to_string())?;
            out.insert(id, (resp.tokens, resp.first_token_step));
        }
        if let Some(s) = stalled {
            // Drain only now, after the server retired everything: what did
            // arrive must be a prefix of the final response.
            let (chunks, resp) = s.collect().map_err(|e| e.to_string())?;
            let concat: Vec<u32> =
                chunks.iter().flat_map(|c| c.tokens.iter().copied()).collect();
            ensure(
                resp.tokens.starts_with(&concat),
                "stalled client streamed bytes that are not a prefix of its response",
            )?;
            out.insert(0, (resp.tokens, resp.first_token_step));
        }
        let m = &server.metrics;
        Ok((
            out,
            m.counter("decode_steps"),
            m.counter("stream_degraded_to_chunk") + m.counter("stream_degraded_to_final"),
        ))
    };
    check(
        "stream-no-head-of-line",
        25,
        0x57B3,
        |rng| {
            let bucket = rng.range(2, 4);
            let shapes: Vec<(u8, u8)> = (0..rng.range(1, 6))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8))
                .collect();
            (bucket, shapes)
        },
        |(bucket, shapes)| {
            let (baseline, base_steps, base_degraded) = run(false, *bucket, shapes)?;
            let (stalled, stall_steps, stall_degraded) = run(true, *bucket, shapes)?;
            ensure_eq(base_degraded, 0, "baseline run has no streaming clients")?;
            ensure(
                stall_degraded >= 1,
                "the capacity-1 stalled client never degraded: property vacuous",
            )?;
            ensure_eq(stalled.len(), baseline.len(), "every request answered in both runs")?;
            ensure_eq(stall_steps, base_steps, "a stalled consumer changed the schedule")?;
            for (id, got) in &stalled {
                let want = &baseline[id];
                ensure(
                    got == want,
                    format!("request {id}: tokens or schedule diverged under a stalled peer"),
                )?;
            }
            Ok(())
        },
    );
}
