//! Property suite for SLO-aware admission ([`pangu_atlas_quant::coordinator::slo`]):
//!
//!   * modeled completion time is monotone in the token-inflation factors —
//!     an honest cost model never prices an inflated trace *cheaper*;
//!   * with identity inflation, a scheduler carrying the full SLO machinery
//!     (config present, requests unconstrained or generously budgeted) is
//!     byte-identical to the plain scheduler — outputs AND counters;
//!   * a satisfiable budget (at or above the cheapest candidate) is never
//!     flagged as a modeled miss;
//!   * downgrades are monotone in the budget: tightening the SLO never
//!     selects a less-degraded (slower) pair, and a miss at a loose budget
//!     stays exactly the same miss at any tighter one.

use std::sync::Arc;

use pangu_atlas_quant::atlas::perf_model::TokenInflation;
use pangu_atlas_quant::coordinator::cost::{AtlasCostModel, CostModel};
use pangu_atlas_quant::coordinator::cot;
use pangu_atlas_quant::coordinator::kv::{KvConfig, PoolHeadroom};
use pangu_atlas_quant::coordinator::request::Request;
use pangu_atlas_quant::coordinator::scheduler::{AdmitGate, Scheduler, SchedulerConfig};
use pangu_atlas_quant::coordinator::slo::{SloPolicy, SloSnapshot};
use pangu_atlas_quant::quant::Precision;
use pangu_atlas_quant::runtime::backend::MockBackend;
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};
use pangu_atlas_quant::util::propcheck::{check, ensure, ensure_eq};

// ---------------------------------------------------------------------------
// Inflation monotonicity
// ---------------------------------------------------------------------------

/// Raising either inflation factor never shrinks the expected trace length
/// or the modeled completion time, and identity inflation prices exactly
/// the legacy `mode_weight * horizon` mapping.
#[test]
fn prop_modeled_completion_monotone_in_inflation() {
    check(
        "slo-inflation-monotone",
        120,
        0x51A0,
        |rng| {
            let prompt = rng.range(1, 64);
            let horizon = rng.range(1, 48);
            let p = rng.range(0, 4); // inclusive: every Precision
            let m = rng.range(0, 2); // inclusive: every CotMode
            // Factors in [1.00, 1.40] / [1.00, 1.60], hi >= lo elementwise.
            let base_i8 = 100 + rng.range(0, 40);
            let base_w4 = 100 + rng.range(0, 60);
            let bump_i8 = rng.range(0, 40);
            let bump_w4 = rng.range(0, 60);
            (prompt, horizon, p, m, base_i8, base_w4, bump_i8, bump_w4)
        },
        |&(prompt, horizon, p, m, bi, bw, di, dw)| {
            let lo = TokenInflation { int8: bi as f64 / 100.0, w4a8: bw as f64 / 100.0 };
            let hi = TokenInflation {
                int8: (bi + di) as f64 / 100.0,
                w4a8: (bw + dw) as f64 / 100.0,
            };
            let precision = Precision::ALL[p];
            let mode = CotMode::ALL[m];
            let cost_lo = AtlasCostModel::openpangu_7b().with_token_inflation(lo);
            let cost_hi = AtlasCostModel::openpangu_7b().with_token_inflation(hi);
            let steps_lo = cost_lo.expected_decode_steps(precision, mode, horizon);
            let steps_hi = cost_hi.expected_decode_steps(precision, mode, horizon);
            ensure(
                steps_lo <= steps_hi,
                format!("expected steps shrank as inflation grew: {steps_lo} -> {steps_hi}"),
            )?;
            let snap = SloSnapshot::unloaded(prompt, horizon);
            let ms_lo = SloPolicy::service_ms(&cost_lo, precision, mode, &snap);
            let ms_hi = SloPolicy::service_ms(&cost_hi, precision, mode, &snap);
            ensure(
                ms_lo <= ms_hi,
                format!("modeled completion shrank as inflation grew: {ms_lo} -> {ms_hi}"),
            )?;
            // Identity inflation is the legacy mapping, exactly.
            let identity = AtlasCostModel::openpangu_7b();
            ensure_eq(
                identity.expected_decode_steps(precision, mode, horizon),
                cot::mode_length_weight(mode) * horizon,
                "identity inflation must reproduce mode_weight * horizon",
            )
        },
    );
}

// ---------------------------------------------------------------------------
// Identity / unconstrained byte-identity with the plain scheduler
// ---------------------------------------------------------------------------

/// Randomized workloads at identity inflation: a scheduler with the SLO
/// policy configured produces byte-identical responses and identical
/// schedule counters to the plain scheduler, both when requests carry no
/// budget (the machinery is structurally inert) and when every budget is
/// generous (rank 0 always fits) — and the generous run records zero
/// downgrades and zero modeled misses (a satisfiable SLO is never a miss).
#[test]
fn prop_identity_inflation_and_unconstrained_slo_are_byte_identical() {
    type RunOut = (Vec<(u64, Vec<u32>, bool, usize)>, [usize; 6], [usize; 3]);
    let run = |with_slo_cfg: bool,
               slo_ms: Option<f64>,
               bucket: usize,
               shapes: &[(u8, u8)],
               paged: bool|
     -> Result<RunOut, String> {
        let tk = Tokenizer::minilang_default();
        let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
        let mut be = MockBackend::new(64, 48, 96, script);
        let mut cfg = SchedulerConfig::fixed(bucket, AdmitGate::Continuous)
            .with_cost(Arc::new(AtlasCostModel::openpangu_7b()));
        if paged {
            cfg = cfg.with_kv(KvConfig::paged(16, 4096));
        }
        if with_slo_cfg {
            cfg = cfg.with_slo(SloPolicy::default());
        }
        let sched = Scheduler::new(&tk, cfg);
        let requests: Vec<Request> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(tag, examples))| {
                let ex: Vec<(Vec<u8>, Vec<u8>)> = (0..examples)
                    .map(|_| (vec![1, 2, 3, 4, 5], vec![5, 4, 3, 2, 1]))
                    .collect();
                let mut r =
                    Request::new(i as u64, "7b-sim", "fp16", CotMode::ALL[tag as usize], ex);
                if let Some(ms) = slo_ms {
                    r = r.with_slo_ms(ms);
                }
                r
            })
            .collect();
        let (resps, report) = sched.run_batch(&mut be, &requests).map_err(|e| e.to_string())?;
        Ok((
            resps
                .into_iter()
                .map(|r| (r.id, r.tokens, r.truncated, r.first_token_step))
                .collect(),
            [
                report.admitted,
                report.completed,
                report.decode_steps,
                report.slot_steps(),
                report.deferred,
                report.joins,
            ],
            [
                report.slo_downgrades_mode,
                report.slo_downgrades_precision,
                report.slo_misses_modeled,
            ],
        ))
    };
    check(
        "slo-identity-byte-identical",
        20,
        0x51B1,
        |rng| {
            let bucket = rng.range(1, 5);
            let shapes: Vec<(u8, u8)> = (0..rng.range(1, 8))
                .map(|_| (rng.range(0, 2) as u8, rng.range(0, 2) as u8))
                .collect();
            let paged = rng.chance(0.5);
            (bucket, shapes, paged)
        },
        |(bucket, shapes, paged)| {
            let (base, base_counters, base_slo) = run(false, None, *bucket, shapes, *paged)?;
            ensure_eq(base_slo, [0; 3], "no SLO config, no SLO counters")?;
            let (inert, inert_counters, inert_slo) = run(true, None, *bucket, shapes, *paged)?;
            ensure(inert == base, "SLO config with unconstrained requests diverged")?;
            ensure_eq(inert_counters, base_counters, "counters diverged (inert SLO)")?;
            ensure_eq(inert_slo, [0; 3], "unconstrained requests fired the SLO path")?;
            let (gen_out, gen_counters, gen_slo) = run(true, Some(1e12), *bucket, shapes, *paged)?;
            ensure(gen_out == base, "generous-budget run diverged from the baseline")?;
            ensure_eq(gen_counters, base_counters, "counters diverged (generous SLO)")?;
            ensure_eq(gen_slo, [0; 3], "a satisfiable SLO recorded a downgrade or miss")?;
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Satisfiable budgets never miss
// ---------------------------------------------------------------------------

/// Any budget at or above the cheapest candidate's modeled completion is
/// satisfiable by construction — the decision must choose a fitting pair
/// and never flag a modeled miss.
#[test]
fn prop_satisfiable_budget_never_flags_a_modeled_miss() {
    check(
        "slo-satisfiable-no-miss",
        150,
        0x51C2,
        |rng| {
            let prompt = rng.range(1, 64);
            let horizon = rng.range(1, 32);
            let queued = [rng.range(0, 5), rng.range(0, 5), rng.range(0, 5)];
            let ap = rng.range(0, 4);
            let am = rng.range(0, 2);
            let i8x = 100 + rng.range(0, 40);
            let w4x = 100 + rng.range(0, 60);
            let slack = rng.range(0, 100);
            (prompt, horizon, queued, ap, am, i8x, w4x, slack)
        },
        |&(prompt, horizon, queued, ap, am, i8x, w4x, slack)| {
            let cost = AtlasCostModel::openpangu_7b().with_token_inflation(TokenInflation {
                int8: i8x as f64 / 100.0,
                w4a8: w4x as f64 / 100.0,
            });
            let policy = SloPolicy::default();
            let arrival = (Precision::ALL[ap], CotMode::ALL[am]);
            let snap = SloSnapshot {
                prompt_tokens: prompt,
                queued_by_mode: queued,
                headroom: None,
                grow_horizon: horizon,
            };
            let wait = SloPolicy::queue_wait_ms(&cost, arrival.0, &snap);
            let cheapest = policy
                .candidates(arrival)
                .into_iter()
                .map(|(p, m)| wait + SloPolicy::service_ms(&cost, p, m, &snap))
                .fold(f64::INFINITY, f64::min);
            let slo_ms = cheapest * (1.0 + slack as f64 / 100.0);
            let d = policy.decide(&cost, arrival, slo_ms, &snap);
            ensure(
                !d.modeled_miss,
                format!("budget {slo_ms} >= cheapest candidate {cheapest} flagged a miss"),
            )?;
            ensure(d.modeled_ms <= slo_ms, "the chosen pair must fit the budget")
        },
    );
}

// ---------------------------------------------------------------------------
// Budget-monotone downgrades
// ---------------------------------------------------------------------------

/// Tightening the budget never selects a less-degraded (earlier-rank,
/// slower) pair, and once a budget is a modeled miss every tighter budget
/// is the *identical* miss (the cheapest candidate does not depend on the
/// budget at all).
#[test]
fn prop_downgrades_monotone_as_the_budget_tightens() {
    check(
        "slo-budget-monotone",
        150,
        0x51D3,
        |rng| {
            let prompt = rng.range(1, 64);
            let horizon = rng.range(1, 32);
            let queued = [rng.range(0, 5), rng.range(0, 5), rng.range(0, 5)];
            let headroom = if rng.chance(0.4) {
                let capacity = rng.range(2, 24);
                Some((capacity, rng.range(0, capacity)))
            } else {
                None
            };
            let ap = rng.range(0, 4);
            let am = rng.range(0, 2);
            let i8x = 100 + rng.range(0, 40);
            let w4x = 100 + rng.range(0, 60);
            // Budgets in [0.1, 10_000] ms; the tighter one is a fraction.
            let hi_tenths = rng.range(1, 100_000);
            let frac = rng.range(0, 100);
            let allow_mode = rng.chance(0.8);
            (prompt, horizon, queued, headroom, ap, am, i8x, w4x, hi_tenths, frac, allow_mode)
        },
        |&(prompt, horizon, queued, headroom, ap, am, i8x, w4x, hi_tenths, frac, allow_mode)| {
            let cost = AtlasCostModel::openpangu_7b().with_token_inflation(TokenInflation {
                int8: i8x as f64 / 100.0,
                w4a8: w4x as f64 / 100.0,
            });
            let policy = SloPolicy { allow_mode_downgrade: allow_mode, ..SloPolicy::default() };
            let arrival = (Precision::ALL[ap], CotMode::ALL[am]);
            let snap = SloSnapshot {
                prompt_tokens: prompt,
                queued_by_mode: queued,
                headroom: headroom.map(|(capacity, free)| PoolHeadroom {
                    page_tokens: 16,
                    used_pages: capacity - free,
                    free_pages: free,
                    capacity_pages: capacity,
                }),
                grow_horizon: horizon,
            };
            let hi = hi_tenths as f64 / 10.0;
            let lo = hi * (frac as f64 / 100.0);
            let d_hi = policy.decide(&cost, arrival, hi, &snap);
            let d_lo = policy.decide(&cost, arrival, lo, &snap);
            if d_hi.modeled_miss {
                ensure(d_lo.modeled_miss, "a tighter budget cannot become feasible")?;
                ensure(d_lo == d_hi, "the miss decision must not depend on the budget")?;
            } else if !d_lo.modeled_miss {
                ensure(
                    d_lo.rank >= d_hi.rank,
                    format!(
                        "tightening the budget moved UP the lattice: rank {} -> {}",
                        d_hi.rank, d_lo.rank
                    ),
                )?;
            }
            Ok(())
        },
    );
}
