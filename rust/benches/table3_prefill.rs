//! Bench: Table 3 — prefill latency sweep FP16 vs INT8 across batch sizes.
//! Regenerates the paper's efficiency table on this substrate.
//!
//!     cargo bench --bench table3_prefill

use pangu_atlas_quant::harness::{table3, Harness};
use pangu_atlas_quant::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]);
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut h = match Harness::open(&dir) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("skipping table3 bench (artifacts unavailable): {e}");
            return;
        }
    };
    let iters = args.usize_or("iters", 5);
    let report = table3::run(&mut h, iters).expect("table3");
    let path = h.write_report("table3", &report).expect("write report");
    println!("report written: {}", path.display());
}
