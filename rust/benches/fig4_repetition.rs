//! Bench: Fig. 4 — repetitive-generation frequency + accuracy split.
//!
//!     cargo bench --bench fig4_repetition [-- --quick 40]

use pangu_atlas_quant::harness::{fig4, Harness};
use pangu_atlas_quant::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]);
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut h = match Harness::open(&dir) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("skipping fig4 bench (artifacts unavailable): {e}");
            return;
        }
    };
    // Time-bounded by default: full benchmarks take many minutes on this
    // 1-core substrate. Pass --full for the complete run, --quick N to tune.
    h.quick = if args.flag("full") {
        None
    } else {
        Some(args.get("quick").and_then(|q| q.parse().ok()).unwrap_or(32))
    };
    let report = fig4::run(&mut h).expect("fig4");
    let path = h.write_report("fig4", &report).expect("write report");
    println!("report written: {}", path.display());
}
