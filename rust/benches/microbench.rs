//! Microbenchmarks of the L3 hot-path components (benchkit): quant mirror
//! GEMMs, Hadamard transform, repetition detector, sampler, JSON, and the
//! continuous-batching scheduler loop (fixed bucket and adaptive ladder)
//! over the mock backend. These run without artifacts — the §Perf
//! profiling substrate for the coordinator layer.
//!
//!     cargo bench --bench microbench
//!     cargo bench --bench microbench -- --smoke   # CI: 1 iteration each
//!     cargo bench --bench microbench -- --smoke --json BENCH_scheduler.json
//!     cargo bench --bench microbench -- --smoke --json out.json \
//!         --baseline BENCH_baseline.json   # CI regression gate
//!
//! `--smoke` runs every bench exactly once with no warmup so CI exercises
//! the bench code paths (they can't bit-rot) without paying measurement
//! time. `--json <path>` additionally writes the groups/medians/notes as a
//! machine-readable perf snapshot (uploaded as a CI artifact — the start
//! of the perf trajectory). `--baseline <path>` compares this run's
//! per-group medians against a saved snapshot and exits non-zero on any
//! group regressing past the threshold (`BENCH_REGRESSION_THRESHOLD` env,
//! default 4.0x — generous because CI runners are noisy and smoke runs
//! measure a single iteration).

use std::cell::RefCell;
use std::sync::Arc;

use pangu_atlas_quant::atlas::perf_model::TokenInflation;
use pangu_atlas_quant::bench_suite::repetition::{detect, RepetitionConfig};
use pangu_atlas_quant::coordinator::admission::{AdmissionQueue, AdmitConfig};
use pangu_atlas_quant::coordinator::cost::{AtlasCostModel, CostModel};
use pangu_atlas_quant::coordinator::fleet;
use pangu_atlas_quant::coordinator::kv::KvConfig;
use pangu_atlas_quant::coordinator::request::Request;
use pangu_atlas_quant::coordinator::sampling;
use pangu_atlas_quant::coordinator::scheduler::{
    AdmitGate, LadderConfig, PreemptConfig, Scheduler, SchedulerConfig,
};
use pangu_atlas_quant::coordinator::slo::SloPolicy;
use pangu_atlas_quant::quant::{hadamard, int4, int8, Precision};
use pangu_atlas_quant::runtime::backend::MockBackend;
use pangu_atlas_quant::tokenizer::{CotMode, Tokenizer};
use pangu_atlas_quant::util::benchkit::{
    regression_threshold, Baseline, BenchConfig, Group, JsonEmitter,
};
use pangu_atlas_quant::util::json::{Json, JsonSlice};
use pangu_atlas_quant::util::prng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path: Option<std::path::PathBuf> = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from);
    let baseline_path: Option<std::path::PathBuf> = args
        .iter()
        .position(|a| a == "--baseline")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from);
    let mut emitter = JsonEmitter::new();
    let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::default() };
    let quick = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
    let mut rng = Rng::new(7);

    // ---- quant mirror -----------------------------------------------
    let mut g = Group::new("quant-mirror");
    let (m, k, n) = (8, 256, 512);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    g.run("quant_act_per_token 8x256", &cfg, || {
        std::hint::black_box(int8::quant_act_per_token(&x, m, k));
    });
    g.run("quant_weight_int8 256x512", &quick, || {
        std::hint::black_box(int8::quant_weight_per_channel(&w, k, n));
    });
    let (xq, xs) = int8::quant_act_per_token(&x, m, k);
    let (wq, ws) = int8::quant_weight_per_channel(&w, k, n);
    g.run("w8a8_matmul 8x256x512", &quick, || {
        std::hint::black_box(int8::w8a8_matmul(&xq, &xs, &wq, &ws, m, k, n));
    });
    let (wq4, _) = int4::quant_weight_per_channel(&w, k, n);
    g.run("int4_pack 256x512", &cfg, || {
        std::hint::black_box(int4::pack(&wq4, k, n));
    });
    let packed = int4::pack(&wq4, k, n);
    g.run("int4_unpack 128x512", &cfg, || {
        std::hint::black_box(int4::unpack(&packed, k / 2, n));
    });
    let mut h = x.clone();
    g.run("fwht 8x256", &cfg, || {
        hadamard::fwht_rows(&mut h, m, k);
        std::hint::black_box(&h);
    });
    emitter.add(&g);
    g.finish();

    // ---- serving hot loop pieces --------------------------------------
    let mut g = Group::new("serving-hot-loop");
    let logits: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    g.run("greedy sample vocab=64", &cfg, || {
        std::hint::black_box(sampling::greedy(&logits));
    });
    let mut srng = Rng::new(3);
    g.run("temperature sample vocab=64", &cfg, || {
        std::hint::black_box(sampling::sample(&logits, 0.8, 8, &mut srng));
    });
    let tokens: Vec<u32> = (0..96).map(|i| (i % 37) as u32).collect();
    let rep_cfg = RepetitionConfig::default();
    g.run("repetition detect len=96", &cfg, || {
        std::hint::black_box(detect(&tokens, &rep_cfg));
    });
    emitter.add(&g);
    g.finish();

    // ---- continuous-batching scheduler over the mock backend -----------
    let mut g = Group::new("scheduler");
    let tk = Tokenizer::minilang_default();
    let modes = [CotMode::NoThink, CotMode::AutoThink, CotMode::SlowThink];
    let examples = vec![(vec![1u8, 2, 3, 4, 5], vec![5u8, 4, 3, 2, 1])];
    let mk_requests = |n: usize| -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, "7b-sim", "int8", modes[i % 3], examples.clone()))
            .collect()
    };
    g.run("admission pick (mode-aware, q=64)", &cfg, || {
        let mut q = AdmissionQueue::new(AdmitConfig::default());
        for r in mk_requests(64) {
            q.push(r);
        }
        while let Some(r) = q.admit(std::time::Instant::now()) {
            std::hint::black_box(r.id);
        }
    });
    for gate in [AdmitGate::Continuous, AdmitGate::WaveBarrier] {
        let name = format!("session 32 reqs bucket=8 ({gate:?})");
        g.run(&name, &quick, || {
            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 22);
            let mut be = MockBackend::new(64, 48, 96, script);
            let sched = Scheduler::new(&tk, SchedulerConfig::fixed(8, gate));
            let (resps, report) =
                sched.run_batch(&mut be, &mk_requests(32)).expect("mock session");
            assert_eq!(resps.len(), 32);
            std::hint::black_box(report.occupancy());
        });
    }
    // Adaptive ladder on a light tail: a slow straggler plus a handful of
    // shorts. The ladder pays the migrate re-shapes; the fixed bucket pays
    // max-bucket decode every step — the bench tracks both so the
    // adaptive path's overhead stays visible.
    let light_requests = || -> Vec<Request> {
        let mut reqs = vec![Request::new(0, "7b-sim", "int8", CotMode::SlowThink, examples.clone())];
        reqs.extend(
            (1..5).map(|i| Request::new(i, "7b-sim", "int8", CotMode::NoThink, examples.clone())),
        );
        reqs
    };
    // Three cost policies over the same workload: the occupancy-only
    // slot-step ladder, the Atlas-roofline-priced ladder, and a fixed max
    // bucket. Each bench line gets a note with the modeled-ms account
    // (SchedReport::modeled_total_ms) next to its raw slot-steps.
    let ladder_cfg = |buckets: Vec<usize>| SchedulerConfig {
        buckets,
        gate: AdmitGate::Continuous,
        ladder: LadderConfig { eval_every: 2, shrink_patience: 2, ..LadderConfig::default() },
        ..SchedulerConfig::default()
    };
    for (name, cfg) in [
        ("light session ladder=[2,4,8] slot-step", ladder_cfg(vec![2, 4, 8])),
        (
            "light session ladder=[2,4,8] atlas-cost",
            ladder_cfg(vec![2, 4, 8]).with_cost(Arc::new(AtlasCostModel::openpangu_7b())),
        ),
        ("light session fixed=8", ladder_cfg(vec![8])),
    ] {
        // Capture the last iteration's report so the modeled-ms note costs
        // no extra workload run.
        let last = RefCell::new(None);
        g.run(name, &quick, || {
            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
            let mut be = MockBackend::new(64, 48, 96, script);
            let sched = Scheduler::new(&tk, cfg.clone());
            let (resps, report) =
                sched.run_batch(&mut be, &light_requests()).expect("mock session");
            assert_eq!(resps.len(), 5);
            std::hint::black_box(report.modeled_total_ms());
            *last.borrow_mut() = Some(report);
        });
        let report = last.into_inner().expect("bench ran at least once");
        g.note(&format!(
            "modeled {:.1} ms ({} slot-steps, {} up / {} down migrations)",
            report.modeled_total_ms(),
            report.slot_steps(),
            report.migrations_up,
            report.migrations_down
        ));
    }
    // Paged KV pool vs whole-window reservation under the same token
    // budget: the paged session admits more concurrently, so it drains the
    // same workload in fewer slot-steps (the note carries the accounting).
    for (name, kv) in [
        ("budgeted session paged kv (16 pages)", KvConfig::paged(16, 16 * 16)),
        ("budgeted session whole-window kv (16 pages)", KvConfig::whole_window(16, 16 * 16)),
    ] {
        let last = RefCell::new(None);
        g.run(name, &quick, || {
            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 30);
            let mut be = MockBackend::new(64, 48, 96, script);
            let cfg = SchedulerConfig::fixed(3, AdmitGate::Continuous).with_kv(kv.clone());
            let sched = Scheduler::new(&tk, cfg);
            let reqs: Vec<Request> = (0..6)
                .map(|i| Request::new(i, "7b-sim", "int8", CotMode::SlowThink, examples.clone()))
                .collect();
            let (resps, report) = sched.run_batch(&mut be, &reqs).expect("mock session");
            assert_eq!(resps.len(), 6);
            std::hint::black_box(report.slot_steps());
            *last.borrow_mut() = Some(report);
        });
        let report = last.into_inner().expect("bench ran at least once");
        g.note(&format!(
            "{} slot-steps, max_live {}, {} deferred, {} pages churned, peak pool util {:.2}",
            report.slot_steps(),
            report.max_live,
            report.deferred,
            report.kv_pages_allocated,
            report.kv_peak_pool_util
        ));
    }
    // Shared-prefix CoW vs the plain paged pool on an n-best workload (six
    // identical prompts) under the same tight budget: sharing admits the
    // full bucket by mapping the cached prefix pages by reference and
    // forking on first write, the plain pool serializes on prompt pages —
    // the notes carry max_live, deferrals, prefix hits, reused pages, and
    // CoW forks.
    for (name, kv) in [
        (
            "n-best session shared-prefix kv (10 pages)",
            KvConfig::paged(16, 10 * 16).with_prefix_sharing(),
        ),
        ("n-best session plain paged kv (10 pages)", KvConfig::paged(16, 10 * 16)),
    ] {
        let last = RefCell::new(None);
        g.run(name, &quick, || {
            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 6);
            let mut be = MockBackend::new(64, 48, 96, script);
            if kv.sharing() {
                // Page-aware mock: reads of multi-mapped pages pass,
                // advancing writes into one are rejected.
                be = be.with_page_tokens(16);
            }
            let cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous).with_kv(kv.clone());
            let sched = Scheduler::new(&tk, cfg);
            let nbest = vec![
                (vec![1u8, 2, 3, 4, 5], vec![5u8, 4, 3, 2, 1]),
                (vec![0u8, 1, 2, 3, 4], vec![4u8, 3, 2, 1, 0]),
                (vec![2u8, 3, 4, 5, 6], vec![6u8, 5, 4, 3, 2]),
            ];
            let reqs: Vec<Request> = (0..6)
                .map(|i| Request::new(i, "7b-sim", "int8", CotMode::NoThink, nbest.clone()))
                .collect();
            let (resps, report) = sched.run_batch(&mut be, &reqs).expect("mock session");
            assert_eq!(resps.len(), 6);
            std::hint::black_box(report.kv_prefix_hits);
            *last.borrow_mut() = Some(report);
        });
        let report = last.into_inner().expect("bench ran at least once");
        g.note(&format!(
            "max_live {}, {} deferred, {} prefix hits, {} pages reused, {} CoW forks, \
             {} pages allocated",
            report.max_live,
            report.deferred,
            report.kv_prefix_hits,
            report.kv_shared_pages_reused,
            report.kv_cow_forks,
            report.kv_pages_allocated
        ));
    }
    // Preempt-vs-truncate on a pool that genuinely starves mid-decode (four
    // 5-page long-CoT sequences over 16 pages): the truncate policy is the
    // cheap-but-lossy baseline, the preempt policy pays re-prefill replay
    // to finish everyone — the notes carry truncations, preemptions, the
    // recomputed-token bill, and both modeled-ms totals.
    for (name, preempt) in [
        ("starved session truncate policy (16 pages)", PreemptConfig::default()),
        ("starved session preempt policy (16 pages)", PreemptConfig::enabled()),
    ] {
        let last = RefCell::new(None);
        g.run(name, &quick, || {
            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 40);
            let mut be = MockBackend::new(64, 48, 96, script);
            let cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous)
                .with_kv(KvConfig::paged(16, 16 * 16))
                .with_preempt(preempt.clone());
            let sched = Scheduler::new(&tk, cfg);
            let two_ex = vec![
                (vec![1u8, 2, 3, 4, 5], vec![5u8, 4, 3, 2, 1]),
                (vec![0u8, 1, 2, 3, 4], vec![4u8, 3, 2, 1, 0]),
            ];
            let reqs: Vec<Request> = (0..4)
                .map(|i| Request::new(i, "7b-sim", "int8", CotMode::SlowThink, two_ex.clone()))
                .collect();
            let (resps, report) = sched.run_batch(&mut be, &reqs).expect("mock session");
            let truncations = resps.iter().filter(|r| r.truncated).count();
            std::hint::black_box((report.preemptions, truncations));
            *last.borrow_mut() = Some((report, truncations));
        });
        let (report, truncations) = last.into_inner().expect("bench ran at least once");
        g.note(&format!(
            "{truncations} truncated, {} preemptions, {} recomputed tokens, \
             {} stall steps, modeled {:.1} ms",
            report.preemptions,
            report.recomputed_tokens,
            report.preempt_stall_steps,
            report.modeled_total_ms()
        ));
    }
    emitter.add(&g);
    g.finish();

    // ---- fleet router ---------------------------------------------------
    // Round-robin vs cost-priced placement over 2 mock devices on a skewed
    // long-CoT workload (long slow_think traces alternating with short
    // no_think ones — the pattern round-robin folds onto one device). The
    // notes carry the fleet completion metrics the e2e gate asserts on:
    // makespan slot-steps, max/min device utilization, and deferrals.
    let mut g = Group::new("fleet-router");
    let skew_requests = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                let mode = if i % 2 == 0 { CotMode::SlowThink } else { CotMode::NoThink };
                let ex = if mode == CotMode::SlowThink {
                    vec![
                        (vec![1u8, 2, 3, 4], vec![4u8, 3, 2, 1]),
                        (vec![2u8, 3, 4, 5], vec![5u8, 4, 3, 2]),
                        (vec![3u8, 4, 5, 6], vec![6u8, 5, 4, 3]),
                    ]
                } else {
                    vec![
                        (vec![1u8, 2, 3], vec![3u8, 2, 1]),
                        (vec![2u8, 3, 4], vec![4u8, 3, 2]),
                    ]
                };
                Request::new(i as u64, "7b-sim", "int8", mode, ex)
            })
            .collect()
    };
    type FleetRouterFactory = fn() -> Box<dyn fleet::RouterPolicy>;
    let policies: [(&str, FleetRouterFactory); 2] = [
        ("fleet 2-dev skewed round-robin", || Box::new(fleet::RoundRobinRouter::new())),
        ("fleet 2-dev skewed cost-priced", || Box::new(fleet::LeastLoadedRouter::new())),
    ];
    for (name, mk_policy) in policies {
        let last = RefCell::new(None);
        g.run(name, &quick, || {
            let sched_cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous)
                .with_kv(KvConfig::paged(16, 10 * 16));
            let cfg = fleet::FleetConfig::homogeneous(
                2,
                sched_cfg,
                AdmitConfig::with_wait(false, std::time::Duration::ZERO),
            );
            let mut f = fleet::Fleet::new(&tk, cfg, mk_policy()).expect("fleet");
            let mut providers: Vec<_> = (0..2)
                .map(|_| {
                    let script =
                        pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 8);
                    pangu_atlas_quant::runtime::backend::MockProvider::new(MockBackend::new(
                        64, 48, 96, script,
                    ))
                })
                .collect();
            let (resps, report) =
                f.run_batch(&mut providers, &skew_requests()).expect("fleet session");
            assert_eq!(resps.len(), 8);
            std::hint::black_box(report.makespan_slot_steps());
            *last.borrow_mut() = Some(report);
        });
        let report = last.into_inner().expect("bench ran at least once");
        let per_dev: Vec<usize> =
            report.devices.iter().map(|d| d.report.slot_steps()).collect();
        g.note(&format!(
            "makespan {} slot-steps (devices max {} / min {}, imbalance {:.2}), \
             {} deferred, {} rebalances, modeled {:.1} ms",
            report.makespan_slot_steps(),
            per_dev.iter().max().copied().unwrap_or(0),
            per_dev.iter().min().copied().unwrap_or(0),
            report.imbalance_ratio(),
            report.rollup().deferred,
            report.rebalances,
            report.rollup().modeled_total_ms()
        ));
    }
    emitter.add(&g);
    g.finish();

    // ---- SLO-aware admission: naive vs inflation-honest pricing ---------
    // A W4A8-heavy slow_think workload under a per-request deadline sized
    // 5% above the *naive* (identity-inflation) service estimate: pricing
    // that ignores token inflation admits the arrival pair as feasible,
    // while the A2-calibrated model (W4A8 emits ~1.24x the FP16 tokens)
    // prices the same trace over budget and downgrades think-mode at
    // admission. The notes carry the downgrade/miss counters next to the
    // slot-step bill so the honest model's shorter drains stay visible.
    let mut g = Group::new("slo-inflation");
    let w4a8_requests = |slo_ms: f64| -> Vec<Request> {
        (0..6)
            .map(|i| {
                Request::new(i as u64, "7b-sim", "w4a8", CotMode::SlowThink, examples.clone())
                    .with_slo_ms(slo_ms)
            })
            .collect()
    };
    let identity = AtlasCostModel::openpangu_7b();
    let sample = Request::new(0, "7b-sim", "w4a8", CotMode::SlowThink, examples.clone());
    let horizon = LadderConfig::default().grow_horizon;
    let naive_steps = identity.expected_decode_steps(Precision::W4A8, CotMode::SlowThink, horizon);
    let naive_ms =
        identity.place_request_ms(Precision::W4A8, sample.prompt_tokens_hint(), naive_steps);
    let slo_ms = naive_ms * 1.05;
    for (name, inflation) in [
        ("slo w4a8-heavy naive pricing", TokenInflation::IDENTITY),
        ("slo w4a8-heavy inflation-honest pricing", TokenInflation::a2_calibrated()),
    ] {
        let last = RefCell::new(None);
        g.run(name, &quick, || {
            let script = pangu_atlas_quant::runtime::backend::minilang_mock_script(&tk, 40);
            let mut be = MockBackend::new(64, 48, 96, script);
            let cfg = SchedulerConfig::fixed(4, AdmitGate::Continuous)
                .with_kv(KvConfig::paged(16, 16 * 16))
                .with_cost(Arc::new(identity.with_token_inflation(inflation)))
                .with_slo(SloPolicy::default());
            let sched = Scheduler::new(&tk, cfg);
            let (resps, report) =
                sched.run_batch(&mut be, &w4a8_requests(slo_ms)).expect("mock session");
            assert_eq!(resps.len(), 6);
            std::hint::black_box(report.slo_misses_modeled);
            *last.borrow_mut() = Some(report);
        });
        let report = last.into_inner().expect("bench ran at least once");
        g.note(&format!(
            "{} mode / {} precision downgrades, {} modeled misses, {} slot-steps, \
             modeled {:.1} ms",
            report.slo_downgrades_mode,
            report.slo_downgrades_precision,
            report.slo_misses_modeled,
            report.slot_steps(),
            report.modeled_total_ms()
        ));
    }
    emitter.add(&g);
    g.finish();

    // ---- substrates ----------------------------------------------------
    let mut g = Group::new("substrates");
    let doc = r#"{"rows":[{"a":1,"b":[1,2,3],"c":"text"},{"a":2,"b":[4,5,6],"c":"more"}]}"#;
    g.run("json parse 80B", &cfg, || {
        std::hint::black_box(Json::parse(doc).unwrap());
    });
    let parsed = Json::parse(doc).unwrap();
    g.run("json serialize", &cfg, || {
        std::hint::black_box(parsed.to_string());
    });
    emitter.add(&g);
    g.finish();

    // ---- host hot paths: zero-copy vs legacy-shape references ----------
    // Each group benches the interned/borrowed path next to an inline
    // reproduction of the pre-refactor shape (per-value owned strings,
    // collect-then-join, unsized buffers), so the "strictly lower median"
    // claim is re-measured on every run and recorded as a note in the
    // snapshot — the groups the checked-in BENCH_baseline.json gates on.
    let reps = 1000usize;

    let mut g = Group::new("tokenizer-encode");
    let enc_examples: Vec<(Vec<u8>, Vec<u8>)> = (0..3u8)
        .map(|i| (vec![i; 5], vec![5 - i; 5]))
        .collect();
    let fast_p50 = {
        let mut out = Vec::with_capacity(tk.prompt_len(&enc_examples));
        g.run("encode_prompt_into reused buffer 3ex x1000", &cfg, || {
            for _ in 0..reps {
                out.clear();
                tk.encode_prompt_into(CotMode::SlowThink, &enc_examples, &mut out);
            }
            std::hint::black_box(out.len());
        })
        .ms
        .p50
    };
    g.run("encode_prompt presized 3ex x1000", &cfg, || {
        for _ in 0..reps {
            std::hint::black_box(tk.encode_prompt(CotMode::SlowThink, &enc_examples).len());
        }
    });
    let legacy_p50 = g
        .run("encode legacy unsized-vec 3ex x1000", &cfg, || {
            for _ in 0..reps {
                // The pre-refactor shape: a growing Vec with no size hint.
                let mut ids = vec![tk.bos, tk.mode_token(CotMode::SlowThink)];
                for (i, (xs, ys)) in enc_examples.iter().enumerate() {
                    if i > 0 {
                        ids.push(tk.sep);
                    }
                    ids.push(tk.tok_in);
                    ids.extend(xs.iter().map(|&v| tk.digit(v)));
                    ids.push(tk.tok_out);
                    ids.extend(ys.iter().map(|&v| tk.digit(v)));
                }
                ids.push(tk.ask);
                std::hint::black_box(ids.len());
            }
        })
        .ms
        .p50;
    g.note(&format!(
        "zero-copy p50 {fast_p50:.4} ms vs legacy-shape {legacy_p50:.4} ms ({:.2}x)",
        legacy_p50 / fast_p50.max(1e-9)
    ));
    emitter.add(&g);
    g.finish();

    let mut g = Group::new("json-parse");
    // A manifest-shaped document (~1 KB): vocab strings, nested minilang
    // block, numeric arrays — the shape the loading hot path actually sees.
    let manifest_doc = {
        let vocab: Vec<Json> = (0..64).map(|i| Json::str(format!("TOKEN_{i:03}"))).collect();
        Json::obj([
            ("vocab", Json::Arr(vocab)),
            (
                "minilang",
                Json::obj([
                    ("mod", Json::num(16.0)),
                    ("seq_len", Json::num(5.0)),
                    ("ops", Json::Arr((0..12).map(|i| Json::str(format!("OP{i}"))).collect())),
                ]),
            ),
            ("serve_buckets", Json::arr_u32(&[1, 2, 4, 8])),
            ("latency_buckets", Json::arr_f64(&[0.5, 1.0, 2.0, 4.0])),
        ])
        .to_string()
    };
    let slice_p50 = g
        .run("slice parse manifest-1KB x100", &cfg, || {
            for _ in 0..100 {
                std::hint::black_box(JsonSlice::parse(&manifest_doc).unwrap());
            }
        })
        .ms
        .p50;
    let owned_p50 = g
        .run("owned parse manifest-1KB x100", &cfg, || {
            // The pre-refactor shape: every string becomes an owned String,
            // every object a BTreeMap, before any field is read.
            for _ in 0..100 {
                std::hint::black_box(Json::parse(&manifest_doc).unwrap());
            }
        })
        .ms
        .p50;
    g.note(&format!(
        "zero-copy p50 {slice_p50:.4} ms vs owned-tree {owned_p50:.4} ms ({:.2}x)",
        owned_p50 / slice_p50.max(1e-9)
    ));
    emitter.add(&g);
    g.finish();

    let mut g = Group::new("render");
    let trace_ids: Vec<u32> = {
        let mut ids = tk.encode_prompt(CotMode::SlowThink, &enc_examples);
        ids.extend([tk.trace, tk.step, tk.ops["REV"], tk.ops["ADD1"]]);
        ids.extend((0..16).map(|i| tk.digit(i % 16)));
        ids.extend([tk.endtrace, tk.prog, tk.ops["REV"], tk.ops["ADD1"], tk.end]);
        ids
    };
    let fast_p50 = {
        let mut out = String::new();
        g.run("render_into reused buffer 48tok x1000", &cfg, || {
            for _ in 0..reps {
                out.clear();
                tk.render_into(&trace_ids, &mut out);
            }
            std::hint::black_box(out.len());
        })
        .ms
        .p50
    };
    g.run("render presized 48tok x1000", &cfg, || {
        for _ in 0..reps {
            std::hint::black_box(tk.render(&trace_ids).len());
        }
    });
    let legacy_p50 = g
        .run("render legacy collect-join 48tok x1000", &cfg, || {
            for _ in 0..reps {
                // The pre-refactor shape: per-token name Vec, then join.
                let s = trace_ids
                    .iter()
                    .map(|&t| tk.name(t))
                    .collect::<Vec<_>>()
                    .join(" ");
                std::hint::black_box(s.len());
            }
        })
        .ms
        .p50;
    g.note(&format!(
        "zero-copy p50 {fast_p50:.4} ms vs legacy-shape {legacy_p50:.4} ms ({:.2}x)",
        legacy_p50 / fast_p50.max(1e-9)
    ));
    emitter.add(&g);
    g.finish();

    if let Some(path) = json_path {
        emitter.write(&path).expect("write perf snapshot");
        println!("\nperf snapshot written to {}", path.display());
    }

    // ---- bench regression gate (criterion save/compare idiom, offline) --
    if let Some(path) = baseline_path {
        let baseline = Baseline::load(&path)
            .unwrap_or_else(|e| panic!("load bench baseline {}: {e}", path.display()));
        let current = Baseline::of_emitter(&emitter);
        // A malformed env threshold fails the gate loudly (no silent
        // fallback to the default).
        let threshold =
            regression_threshold(4.0).unwrap_or_else(|e| panic!("bench regression gate: {e}"));
        let report = baseline.compare(&current, threshold);
        print!("\n{}", report.render());
        if !report.passed() {
            std::process::exit(1);
        }
    }
}
