//! Bench: Fig. 1 — channel-wise |value| distributions under W4A8 configs
//! (baseline vs SmoothQuant vs Hadamard), from the calibration dump.
//!
//!     cargo bench --bench fig1_distributions

use pangu_atlas_quant::harness::{fig1, Harness};
use pangu_atlas_quant::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]);
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut h = match Harness::open(&dir) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("skipping fig1 bench (artifacts unavailable): {e}");
            return;
        }
    };
    let report = fig1::run(&mut h).expect("fig1");
    let path = h.write_report("fig1", &report).expect("write report");
    println!("report written: {}", path.display());
}
