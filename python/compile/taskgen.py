"""Benchmark + training-corpus generator for the MiniLang substrate.

Produces the two evaluation suites standing in for the paper's benchmarks:

  * HumanEval-S — 164 tasks, programs of length 2-3 (compositional, harder)
  * MBPP-S      — 257 tasks, programs of length 1-2 (simpler)

and the training corpus used by train.py. All sampling is seeded; the suites
are disjoint from the training stream by construction (signature dedup), so
benchmark accuracy measures generalisation, as with real HumanEval/MBPP.
"""

from __future__ import annotations

import random

from . import minilang as ml

N_EXAMPLES = 3    # I/O examples shown in the prompt
N_TESTS = 3       # held-out test cases used for pass@1 scoring

# Ops sampled into tasks. The full ISA (minilang.OPS) stays in the vocab and
# VM; generation restricts to a distinguishable subset — near-duplicate ops
# (ADD2 vs ADD1, ROTR vs ROTL, SORTD vs SORT, SUB1) make 3-example induction
# needlessly ambiguous at simulated-model scale.
ACTIVE_OPS = ["ADD1", "MUL2", "NEG", "REV", "SORT", "ROTL", "SWAP", "CUMSUM"]


def _rand_seq(rng: random.Random) -> tuple[int, ...]:
    return tuple(rng.randrange(ml.MOD) for _ in range(ml.SEQ_LEN))


def _sample_program(rng: random.Random, min_len: int, max_len: int,
                    p_long: float | None = None) -> list[str]:
    """Sample a program; reject immediate-inverse pairs (REV REV etc.),
    which collapse to shorter behaviour and distort difficulty bands.

    p_long: when given (and the range is non-trivial), probability of
    drawing max_len rather than uniform — the difficulty dial that separates
    HumanEval-S from MBPP-S."""
    inverse = {("REV", "REV"), ("NEG", "NEG"), ("SWAP", "SWAP"),
               ("ROTL", "ROTR"), ("ROTR", "ROTL"),
               ("ADD1", "SUB1"), ("SUB1", "ADD1")}
    while True:
        if p_long is not None and max_len > min_len:
            n = max_len if rng.random() < p_long else min_len
        else:
            n = rng.randint(min_len, max_len)
        ops = [rng.choice(ACTIVE_OPS) for _ in range(n)]
        if any((a, b) in inverse for a, b in zip(ops, ops[1:])):
            continue
        return ops


def sample_task(rng: random.Random, min_len: int, max_len: int,
                p_long: float | None = None) -> dict:
    """A task = program + prompt examples + held-out tests.

    Rejection criteria keep tasks well-posed:
      * program must act non-trivially on at least one prompt example
        (otherwise the examples cannot identify any behaviour);
      * prompt inputs must be pairwise distinct.
    """
    while True:
        ops = _sample_program(rng, min_len, max_len, p_long)
        inputs = []
        seen = set()
        for _ in range(N_EXAMPLES + N_TESTS):
            xs = _rand_seq(rng)
            while xs in seen:
                xs = _rand_seq(rng)
            seen.add(xs)
            inputs.append(xs)
        pairs = [(xs, ml.run_program(ops, xs)) for xs in inputs]
        if all(xs == ys for xs, ys in pairs[:N_EXAMPLES]):
            continue  # examples show the identity: ill-posed
        return {
            "program": ops,
            "examples": pairs[:N_EXAMPLES],
            "tests": pairs[N_EXAMPLES:],
            "hard": len(ops) >= 2,
        }


def _signature(task: dict) -> tuple:
    return tuple(task["examples"])


def make_benchmark(name: str, n_tasks: int, min_len: int, max_len: int,
                   seed: int, exclude: set | None = None,
                   p_long: float | None = None) -> dict:
    """Generate a deduplicated benchmark suite."""
    rng = random.Random(seed)
    exclude = set() if exclude is None else exclude
    tasks, sigs = [], set()
    while len(tasks) < n_tasks:
        t = sample_task(rng, min_len, max_len, p_long)
        sig = _signature(t)
        if sig in sigs or sig in exclude:
            continue
        sigs.add(sig)
        t["id"] = len(tasks)
        tasks.append(t)
    return {"name": name, "tasks": tasks, "sigs": sigs}


def benchmark_json(bench: dict) -> dict:
    """JSON-serializable form consumed by the Rust dataset loader."""
    return {
        "name": bench["name"],
        "seq_len": ml.SEQ_LEN,
        "mod": ml.MOD,
        "tasks": [
            {
                "id": t["id"],
                "program": t["program"],
                "hard": t["hard"],
                "examples": [[list(i), list(o)] for i, o in t["examples"]],
                "tests": [[list(i), list(o)] for i, o in t["tests"]],
            }
            for t in bench["tasks"]
        ],
    }


def training_stream(seed: int, exclude: set, n: int,
                    mode_weights=(1, 1, 1)) -> list[dict]:
    """Training examples: a task + a sampled CoT mode. Tasks colliding with
    benchmark signatures are rejected so the suites stay held out."""
    rng = random.Random(seed)
    modes = ["no_think", "auto_think", "slow_think"]
    out = []
    while len(out) < n:
        t = sample_task(rng, 1, 2, p_long=0.5)
        if _signature(t) in exclude:
            continue
        t["mode"] = rng.choices(modes, weights=mode_weights)[0]
        out.append(t)
    return out


def render_training_example(task: dict) -> tuple[list[int], list[int]]:
    """(prompt_ids, completion_ids) for one training example."""
    prompt = ml.encode_prompt(task["mode"], task["examples"])
    completion = ml.encode_completion(
        task["mode"], task["program"], task["examples"][0][0], task["hard"]
    )
    return prompt, completion
