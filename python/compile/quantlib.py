"""Post-training quantization pipeline (the paper's Sec. 3.2 / 4.1 protocol).

Given trained fp parameters and calibration data, produce the per-variant
quantized "spec" pytrees consumed by model.make_prefill/make_decode:

  * fp16            — fp baseline (fp32 on this substrate; see DESIGN.md §2)
  * int8            — W8A8: per-channel int8 weights, per-token int8 acts
  * w4a8            — baseline W4A8: per-channel int4 (packed) weights
  * w4a8_smooth     — SmoothQuant (alpha = 0.5) folded, then W4A8
  * w4a8_hadamard   — Hadamard rotation folded, then W4A8

All quantization is symmetric with calibrated scales and no retraining,
matching the paper's setup. The same unified pipeline quantizes every
variant so comparisons are apples-to-apples (paper Sec. 4.1).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import ref

VARIANTS = ("fp16", "int8", "w4a8", "w4a8_smooth", "w4a8_hadamard")

SMOOTH_ALPHA = 0.5


def calibrate(cfg: M.ModelConfig, params: dict, calib_tokens: jnp.ndarray,
              batch: int = 16) -> dict[str, np.ndarray]:
    """Per-linear input-channel abs-max over the calibration set.

    calib_tokens: int32 [N, S] prompts drawn from downstream task data
    (the paper calibrates on downstream task data, Sec. 4.1).
    """
    stats: dict[str, np.ndarray] = {}
    for i in range(0, calib_tokens.shape[0], batch):
        chunk = calib_tokens[i : i + batch]
        got = M.capture_linear_inputs(cfg, params, chunk)
        for key, amax in got.items():
            amax = np.asarray(amax)
            stats[key] = np.maximum(stats[key], amax) if key in stats else amax
    return stats


def _quant_spec_int8(w: jnp.ndarray, smooth_inv=None) -> dict:
    wq, ws = ref.quant_weight_int8(w)
    spec = {"kind": "int8", "wq": wq, "ws": ws}
    if smooth_inv is not None:
        spec["smooth_inv"] = jnp.asarray(smooth_inv, jnp.float32)
    return spec


def _quant_spec_w4a8(w: jnp.ndarray, smooth_inv=None, had=False) -> dict:
    wq, ws = ref.quant_weight_int4(w)
    spec = {"kind": "w4a8", "wp": ref.pack_int4(wq), "ws": ws, "had": had}
    if smooth_inv is not None:
        spec["smooth_inv"] = jnp.asarray(smooth_inv, jnp.float32)
    return spec


def quantize(cfg: M.ModelConfig, params: dict, variant: str,
             calib_stats: dict[str, np.ndarray] | None = None) -> dict:
    """Build the spec pytree for one variant.

    Embedding / unembedding / norms stay fp (standard practice; the paper
    quantizes the transformer linears).
    """
    if variant == "fp16":
        return M.fp_specs(params)
    if variant in ("int8",):
        make = _quant_spec_int8
    elif variant.startswith("w4a8"):
        make = _quant_spec_w4a8
    else:
        raise ValueError(f"unknown variant {variant!r}")

    needs_calib = variant == "w4a8_smooth"
    if needs_calib and calib_stats is None:
        raise ValueError("w4a8_smooth requires calibration statistics")

    out = {"embed": params["embed"], "lnf": params["lnf"], "layers": []}
    for li, layer in enumerate(params["layers"]):
        sl = {"ln1": layer["ln1"], "ln2": layer["ln2"]}
        for name in M.LINEAR_NAMES:
            w = layer[name]
            if variant == "w4a8_smooth":
                s = ref.smooth_scales(
                    jnp.asarray(calib_stats[f"L{li}.{name}"]), w, SMOOTH_ALPHA
                )
                w_folded = ref.fold_smooth(w, s)
                sl[name] = make(w_folded, smooth_inv=1.0 / s)
            elif variant == "w4a8_hadamard":
                sl[name] = make(ref.fold_hadamard(w), had=True)
            else:
                sl[name] = make(w)
        out["layers"].append(sl)
    return out


# ---------------------------------------------------------------------------
# Fig. 1 support: channel-wise |value| distributions per W4A8 configuration.
# ---------------------------------------------------------------------------


def channel_distributions(cfg: M.ModelConfig, params: dict,
                          calib_stats: dict[str, np.ndarray],
                          layer: int = 0, linear: str = "wg") -> dict:
    """Per-input-channel abs-max of the *quantizer input* under each W4A8
    configuration — the quantity Fig. 1 plots. For the baseline this is the
    raw weight column amax; SmoothQuant and Hadamard report the transformed
    weight, whose flattened distribution is the paper's claim."""
    w = params["layers"][layer][linear]
    key = f"L{layer}.{linear}"
    act_amax = jnp.asarray(calib_stats[key])

    def chan_amax(mat):
        return np.asarray(jnp.max(jnp.abs(mat), axis=1))

    s = ref.smooth_scales(act_amax, w, SMOOTH_ALPHA)
    return {
        "layer": layer,
        "linear": linear,
        "weight_baseline": chan_amax(w).tolist(),
        "weight_smooth": chan_amax(ref.fold_smooth(w, s)).tolist(),
        "weight_hadamard": chan_amax(ref.fold_hadamard(w)).tolist(),
        "act_baseline": np.asarray(act_amax).tolist(),
        "act_smooth": np.asarray(act_amax / s).tolist(),
    }


# ---------------------------------------------------------------------------
# Quantization-error metrics (unit-test + EXPERIMENTS.md support).
# ---------------------------------------------------------------------------


def weight_quant_error(w: jnp.ndarray, variant: str,
                       act_amax: np.ndarray | None = None) -> float:
    """Relative Frobenius reconstruction error of the weight under a
    variant's quantizer (activation side excluded)."""
    if variant == "int8":
        wq, ws = ref.quant_weight_int8(w)
        deq = wq.astype(jnp.float32) * ws
        ref_w = w
    elif variant == "w4a8":
        wq, ws = ref.quant_weight_int4(w)
        deq = wq.astype(jnp.float32) * ws
        ref_w = w
    elif variant == "w4a8_smooth":
        s = ref.smooth_scales(jnp.asarray(act_amax), w, SMOOTH_ALPHA)
        wf = ref.fold_smooth(w, s)
        wq, ws = ref.quant_weight_int4(wf)
        deq = wq.astype(jnp.float32) * ws
        ref_w = wf
    elif variant == "w4a8_hadamard":
        wf = ref.fold_hadamard(w)
        wq, ws = ref.quant_weight_int4(wf)
        deq = wq.astype(jnp.float32) * ws
        ref_w = wf
    else:
        raise ValueError(variant)
    num = jnp.linalg.norm(deq - ref_w)
    den = jnp.linalg.norm(ref_w)
    return float(num / den)
