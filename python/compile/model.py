"""pangu-lite: the L2 JAX model (build-time only; never on the request path).

A decoder-only transformer in the openPangu-Embedded architecture family
(RMSNorm, RoPE multi-head attention, SwiGLU MLP, tied embeddings) at two
simulated scales standing in for openPangu-Embedded-1B and -7B (DESIGN.md §2).

Three entry points:

  * forward_seq   — full-sequence logits, fp only; used by train.py and as
                    the teacher for calibration capture.
  * make_prefill  — (tokens [B,S], true_lens [B]) -> (last-valid logits
                    [B,V], kv cache); quantized linears call the L1 Pallas
                    kernels, so the lowered HLO contains the fused
                    quantize -> int GEMM -> dequant regions.
  * make_decode   — (tokens [B], kv, pos [B]) -> (logits [B,V], kv); one
                    step with device-resident KV (per-element positions, as
                    required by continuous batching in the Rust scheduler).

Weights are *closed over* at lower time, so every exported executable is
self-contained (weights are HLO constants — the "no format conversion on the
hot path" property of the paper's framework).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import minilang as ml
from .kernels import ref
from .kernels.hadamard import hadamard
from .kernels.quant_act import quant_act
from .kernels.w4a8_gemm import w4a8_gemm
from .kernels.w8a8_gemm import w8a8_gemm

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int = ml.VOCAB_SIZE
    max_seq: int = ml.MAX_SEQ
    prompt_len: int = ml.PROMPT_LEN
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def params_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return v * d + self.n_layers * per_layer + d


# Simulated stand-ins for openPangu-Embedded-1B / -7B. Dimensions are powers
# of two so the Hadamard rotation applies to every linear input.
CONFIGS = {
    "1b-sim": ModelConfig("1b-sim", d_model=128, n_layers=4, n_heads=4, d_ff=256),
    "7b-sim": ModelConfig("7b-sim", d_model=256, n_layers=8, n_heads=8, d_ff=512),
}

# The seven quantizable linears of each block, with (in, out) dim names.
LINEAR_NAMES = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")


def linear_dims(cfg: ModelConfig, name: str) -> tuple[int, int]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "wg": (d, f), "wu": (d, f), "wd": (f, d),
    }[name]


# ---------------------------------------------------------------------------
# Parameters (fp training form): nested dict of jnp arrays.
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 1 + cfg.n_layers)
    d = cfg.d_model

    def dense(k, shape):
        fan_in = shape[0]
        return (jax.random.normal(k, shape) / np.sqrt(fan_in)).astype(jnp.float32)

    params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, d)) * 0.02).astype(jnp.float32),
        "lnf": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[1 + li], len(LINEAR_NAMES))
        layer = {"ln1": jnp.ones((d,), jnp.float32), "ln2": jnp.ones((d,), jnp.float32)}
        for name, k in zip(LINEAR_NAMES, lk):
            layer[name] = dense(k, linear_dims(cfg, name))
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# Quantized linear specs. A "spec" is what apply_linear consumes:
#   {"kind": "fp",   "w": f32[K,N]}
#   {"kind": "int8", "wq": i8[K,N], "ws": f32[1,N], "smooth_inv": f32[K]?}
#   {"kind": "w4a8", "wp": i8[K/2,N], "ws": f32[1,N],
#                    "smooth_inv": f32[K]?, "had": bool}
# smooth_inv multiplies the activation (X' = X * smooth_inv = X S^{-1});
# had=True applies the online Hadamard rotation to the activation.
# ---------------------------------------------------------------------------


def apply_linear(spec: dict, x2d: jnp.ndarray) -> jnp.ndarray:
    kind = spec["kind"]
    if kind == "fp":
        return x2d @ spec["w"]
    if spec.get("had", False):
        x2d = hadamard(x2d)
    if "smooth_inv" in spec:
        x2d = x2d * spec["smooth_inv"][None, :]
    xq, xs = quant_act(x2d)
    if kind == "int8":
        return w8a8_gemm(xq, xs, spec["wq"], spec["ws"])
    if kind == "w4a8":
        return w4a8_gemm(xq, xs, spec["wp"], spec["ws"])
    raise ValueError(f"unknown linear kind {kind!r}")


def fp_specs(params: dict) -> dict:
    """Wrap fp params into spec form (the FP16-baseline 'variant')."""
    out = {"embed": params["embed"], "lnf": params["lnf"], "layers": []}
    for layer in params["layers"]:
        sl = {"ln1": layer["ln1"], "ln2": layer["ln2"]}
        for name in LINEAR_NAMES:
            sl[name] = {"kind": "fp", "w": layer[name]}
        out["layers"].append(sl)
    return out


# ---------------------------------------------------------------------------
# Core blocks
# ---------------------------------------------------------------------------


def rms_norm(x, g, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope(x, positions, theta):
    """x [..., T, H, Dh], positions [..., T] -> rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # positions [..., T] -> angles [..., T, 1, half] (broadcast over heads)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attn_weights(scores, mask):
    scores = jnp.where(mask, scores, -1e9)
    return jax.nn.softmax(scores, axis=-1)


# ---------------------------------------------------------------------------
# forward_seq: fp full-sequence logits (training / calibration teacher).
# ---------------------------------------------------------------------------


def forward_seq(cfg: ModelConfig, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens int32 [B, S] -> logits f32 [B, S, V]. Pure fp, causal."""
    b, s = tokens.shape
    h = params["embed"][tokens]  # [B, S, D]
    positions = jnp.arange(s)[None, :].astype(jnp.int32)
    causal = jnp.tril(jnp.ones((s, s), bool))[None, None]

    for layer in params["layers"]:
        x = rms_norm(h, layer["ln1"], cfg.eps)
        q = (x @ layer["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (x @ layer["wk"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        v = (x @ layer["wv"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        att = _attn_weights(scores, causal)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, cfg.d_model)
        h = h + ctx @ layer["wo"]

        x = rms_norm(h, layer["ln2"], cfg.eps)
        gate = jax.nn.silu(x @ layer["wg"])
        up = x @ layer["wu"]
        h = h + (gate * up) @ layer["wd"]

    h = rms_norm(h, params["lnf"], cfg.eps)
    return h @ params["embed"].T


# ---------------------------------------------------------------------------
# Calibration capture: per-linear input abs-max over a batch of sequences.
# ---------------------------------------------------------------------------


def capture_linear_inputs(cfg: ModelConfig, params: dict, tokens: jnp.ndarray) -> dict:
    """Run forward_seq capturing max|X_j| per input channel of every linear.

    Returns {"L{li}.{name}": f32[K]} — the calibration statistics feeding
    SmoothQuant scale computation and the Fig. 1 distribution dump.
    """
    b, s = tokens.shape
    stats: dict[str, jnp.ndarray] = {}

    def record(key, x2d):
        amax = jnp.max(jnp.abs(x2d), axis=0)
        stats[key] = jnp.maximum(stats[key], amax) if key in stats else amax

    h = params["embed"][tokens]
    positions = jnp.arange(s)[None, :].astype(jnp.int32)
    causal = jnp.tril(jnp.ones((s, s), bool))[None, None]

    for li, layer in enumerate(params["layers"]):
        x = rms_norm(h, layer["ln1"], cfg.eps)
        x2 = x.reshape(-1, cfg.d_model)
        for name in ("wq", "wk", "wv"):
            record(f"L{li}.{name}", x2)
        q = (x @ layer["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (x @ layer["wk"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        v = (x @ layer["wv"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        att = _attn_weights(scores, causal)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, cfg.d_model)
        record(f"L{li}.wo", ctx.reshape(-1, cfg.d_model))
        h = h + ctx @ layer["wo"]

        x = rms_norm(h, layer["ln2"], cfg.eps)
        x2 = x.reshape(-1, cfg.d_model)
        record(f"L{li}.wg", x2)
        record(f"L{li}.wu", x2)
        gate = jax.nn.silu(x @ layer["wg"])
        up = x @ layer["wu"]
        inner = gate * up
        record(f"L{li}.wd", inner.reshape(-1, cfg.d_ff))
        h = h + inner @ layer["wd"]

    return stats


# ---------------------------------------------------------------------------
# Serving graphs: prefill + decode with device-resident KV.
# KV layout: [L, 2, B, H, Smax, Dh] (2 = key/value planes).
# ---------------------------------------------------------------------------


def _qkv(cfg, slayer, x2d, b, t):
    q = apply_linear(slayer["wq"], x2d).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = apply_linear(slayer["wk"], x2d).reshape(b, t, cfg.n_heads, cfg.head_dim)
    v = apply_linear(slayer["wv"], x2d).reshape(b, t, cfg.n_heads, cfg.head_dim)
    return q, k, v


def prefill_fn(cfg: ModelConfig, specs: dict, tokens: jnp.ndarray,
               true_lens: jnp.ndarray):
    """tokens int32 [B, S_p] (right-padded), true_lens int32 [B]
    -> (last-valid logits f32 [B, V], kv f32 [L, 2, B, H, Smax, Dh])."""
    b, s = tokens.shape
    smax = cfg.max_seq
    h = specs["embed"][tokens]
    positions = jnp.arange(s)[None, :].astype(jnp.int32)
    causal = jnp.tril(jnp.ones((s, s), bool))[None, None]

    kv_layers = []
    for slayer in specs["layers"]:
        x = rms_norm(h, slayer["ln1"], cfg.eps)
        q, k, v = _qkv(cfg, slayer, x.reshape(b * s, cfg.d_model), b, s)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        att = _attn_weights(scores, causal)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * s, cfg.d_model)
        h = h + apply_linear(slayer["wo"], ctx).reshape(b, s, cfg.d_model)

        x = rms_norm(h, slayer["ln2"], cfg.eps)
        x2 = x.reshape(b * s, cfg.d_model)
        inner = jax.nn.silu(apply_linear(slayer["wg"], x2)) * apply_linear(slayer["wu"], x2)
        h = h + apply_linear(slayer["wd"], inner).reshape(b, s, cfg.d_model)

        # KV entries for positions [0, S); zero beyond (later decode steps
        # overwrite slots >= true_len before they become attendable).
        pad = ((0, 0), (0, 0), (0, smax - s), (0, 0))
        k_c = jnp.pad(k.transpose(0, 2, 1, 3), pad)  # [B, H, Smax, Dh]
        v_c = jnp.pad(v.transpose(0, 2, 1, 3), pad)
        kv_layers.append(jnp.stack([k_c, v_c], axis=0))  # [2, B, H, Smax, Dh]

    kv = jnp.stack(kv_layers, axis=0)
    h = rms_norm(h, specs["lnf"], cfg.eps)
    # Select each row's last real position (true_len - 1).
    idx = (true_lens - 1).astype(jnp.int32)
    h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]  # [B, D]
    logits = h_last @ specs["embed"].T
    return logits, kv


def decode_fn(cfg: ModelConfig, specs: dict, tokens: jnp.ndarray,
              kv: jnp.ndarray, pos: jnp.ndarray):
    """One decode step with per-element positions.

    tokens int32 [B], kv f32 [L, 2, B, H, Smax, Dh], pos int32 [B]
    -> (logits f32 [B, V], new kv). Writes K/V at pos[b], attends to
    slots <= pos[b].
    """
    b = tokens.shape[0]
    smax = cfg.max_seq
    h = specs["embed"][tokens]  # [B, D]
    pos_f = pos[:, None]  # [B, 1] for rope's T axis

    new_layers = []
    for li, slayer in enumerate(specs["layers"]):
        x = rms_norm(h, slayer["ln1"], cfg.eps)
        q, k, v = _qkv(cfg, slayer, x, b, 1)  # [B, 1, H, Dh]
        q = rope(q, pos_f, cfg.rope_theta)[:, 0]  # [B, H, Dh]
        k = rope(k, pos_f, cfg.rope_theta)[:, 0]
        v = v[:, 0]

        def upd(plane_b, new_b, p):
            # plane_b [H, Smax, Dh], new_b [H, Dh]
            return jax.lax.dynamic_update_slice(plane_b, new_b[:, None, :], (0, p, 0))

        k_cache = jax.vmap(upd)(kv[li, 0], k, pos)  # [B, H, Smax, Dh]
        v_cache = jax.vmap(upd)(kv[li, 1], v, pos)
        new_layers.append(jnp.stack([k_cache, v_cache], axis=0))

        scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / np.sqrt(cfg.head_dim)
        mask = (jnp.arange(smax)[None, :] <= pos[:, None])[:, None, :]  # [B, 1, Smax]
        att = _attn_weights(scores, mask)
        ctx = jnp.einsum("bhs,bhsd->bhd", att, v_cache).reshape(b, cfg.d_model)
        h = h + apply_linear(slayer["wo"], ctx)

        x = rms_norm(h, slayer["ln2"], cfg.eps)
        inner = jax.nn.silu(apply_linear(slayer["wg"], x)) * apply_linear(slayer["wu"], x)
        h = h + apply_linear(slayer["wd"], inner)

    kv_new = jnp.stack(new_layers, axis=0)
    h = rms_norm(h, specs["lnf"], cfg.eps)
    logits = h @ specs["embed"].T
    return logits, kv_new


def make_prefill(cfg: ModelConfig, specs: dict):
    """Close over specs -> jit-able fn(tokens, true_lens)."""
    return functools.partial(prefill_fn, cfg, specs)


def make_decode(cfg: ModelConfig, specs: dict):
    return functools.partial(decode_fn, cfg, specs)


def kv_shape(cfg: ModelConfig, batch: int) -> tuple[int, ...]:
    return (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)


def state_len(cfg: ModelConfig, batch: int) -> int:
    """Flat serving-state length: logits [B, V] then kv (DESIGN.md §3)."""
    return batch * cfg.vocab + int(np.prod(kv_shape(cfg, batch)))


# ---------------------------------------------------------------------------
# Spec flattening: arrays out, static structure (kinds/flags) closed over.
# jax.tree flattening would treat the str/bool fields as leaves, so we walk
# the structure ourselves. Deterministic order = the order produced here,
# recorded in the manifest and mirrored by the Rust weight loader.
# ---------------------------------------------------------------------------


def flatten_specs(specs: dict):
    """-> (names, arrays, rebuild) where rebuild(arrays) reconstructs specs."""
    names: list[str] = []
    arrays: list[jnp.ndarray] = []
    paths: list[tuple] = []

    def visit(node, path):
        if isinstance(node, dict):
            for key in sorted(node):
                visit(node[key], path + (key,))
        elif isinstance(node, (list, tuple)):
            for i, item in enumerate(node):
                visit(item, path + (i,))
        elif isinstance(node, (jnp.ndarray, np.ndarray)):
            names.append(".".join(str(p) for p in path))
            arrays.append(jnp.asarray(node))
            paths.append(path)
        # str / bool / float statics stay in the closed-over structure

    visit(specs, ())

    def rebuild(arrs):
        import copy
        out = copy.deepcopy(_strip_arrays(specs))
        for path, arr in zip(paths, arrs):
            node = out
            for p in path[:-1]:
                node = node[p]
            node[path[-1]] = arr
        return out

    return names, arrays, rebuild


def _strip_arrays(node):
    if isinstance(node, dict):
        return {k: _strip_arrays(v) for k, v in node.items()}
    if isinstance(node, list):
        return [_strip_arrays(v) for v in node]
    if isinstance(node, tuple):
        return [_strip_arrays(v) for v in node]
    if isinstance(node, (jnp.ndarray, np.ndarray)):
        return None  # placeholder filled by rebuild
    return node


# ---------------------------------------------------------------------------
# Serving graphs with the flat-state ABI (de-risked in rust/tests/derisk.rs):
#   prefill(weights..., tokens [B,Sp], true_lens [B]) -> f32[B*V + NKV]
#   decode (weights..., tokens [B], state, pos [B])   -> f32[B*V + NKV]
#   readout(state)                                    -> f32[B, V]
# Logits first so the readout executable is a prefix slice; KV never leaves
# the device.
# ---------------------------------------------------------------------------


def serve_prefill(cfg: ModelConfig, specs: dict):
    names, arrays, rebuild = flatten_specs(specs)

    def fn(arrs, tokens, true_lens):
        sp = rebuild(arrs)
        logits, kv = prefill_fn(cfg, sp, tokens, true_lens)
        return jnp.concatenate([logits.ravel(), kv.ravel()])

    return fn, names, arrays


def serve_decode(cfg: ModelConfig, specs: dict, batch: int):
    names, arrays, rebuild = flatten_specs(specs)
    nlogits = batch * cfg.vocab
    kshape = kv_shape(cfg, batch)

    def fn(arrs, tokens, state, pos):
        sp = rebuild(arrs)
        kv = state[nlogits:].reshape(kshape)
        logits, kv_new = decode_fn(cfg, sp, tokens, kv, pos)
        return jnp.concatenate([logits.ravel(), kv_new.ravel()])

    return fn, names, arrays


def serve_readout(cfg: ModelConfig, batch: int):
    nlogits = batch * cfg.vocab

    def fn(state):
        return state[:nlogits].reshape(batch, cfg.vocab)

    return fn
