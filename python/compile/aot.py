"""AOT artifact pipeline: the single entry point behind `make artifacts`.

Runs the entire build-time path (Python never appears at serve time):

  1. generate MiniLang benchmark suites + training corpus   (taskgen)
  2. train pangu-lite at both simulated scales              (train)
  3. calibrate on downstream-task prompts                   (quantlib)
  4. quantize weights per variant                           (quantlib)
  5. export serving executables as HLO text + PTEN weights  (artifactio)
  6. write manifest.json, datasets, Fig.1 channel dump

Idempotent: every product is skipped when its file already exists
(`--force` rebuilds everything, `--force-export` re-exports graphs only).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from . import minilang as ml
from . import model as M
from . import quantlib as Q
from . import taskgen
from . import train as T
from .artifactio import lower_to_hlo_text, write_pten

# ---------------------------------------------------------------------------
# Build plan
# ---------------------------------------------------------------------------

MODEL_VARIANTS = {
    "1b-sim": ["fp16", "int8"],
    "7b-sim": ["fp16", "int8", "w4a8", "w4a8_smooth", "w4a8_hadamard"],
}

SERVE_BUCKETS = [1, 8]            # accuracy / serving path (prefill+decode+readout)
LATENCY_BUCKETS = [2, 4, 8, 16, 32]  # Table 3 prefill sweep (7b-sim, fp16+int8)

TRAIN_PLAN = {
    # (steps, batch, peak_lr, seed) — budgets tuned on the 1-core build host;
    # the 7b-sim scale gets a longer effective token budget, reproducing the
    # paper's 1B-vs-7B capability gap.
    "1b-sim": dict(steps=1200, batch=48, peak_lr=1.5e-3, seed=11),
    "7b-sim": dict(steps=900, batch=32, peak_lr=1.2e-3, seed=13),
}

BENCH_SEEDS = {"humaneval_s": 20101, "mbpp_s": 20202}
TRAIN_STREAM_SEED = 777
CALIB_PROMPTS = 64


# ---------------------------------------------------------------------------
# Parameter (de)serialization
# ---------------------------------------------------------------------------


def save_params(path, params):
    flat = {"embed": np.asarray(params["embed"]), "lnf": np.asarray(params["lnf"])}
    for i, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            flat[f"layers.{i}.{k}"] = np.asarray(v)
    np.savez(path, **flat)


def load_params(path, cfg):
    z = np.load(path)
    params = {"embed": jnp.asarray(z["embed"]), "lnf": jnp.asarray(z["lnf"]), "layers": []}
    for i in range(cfg.n_layers):
        layer = {}
        for k in ("ln1", "ln2") + M.LINEAR_NAMES:
            layer[k] = jnp.asarray(z[f"layers.{i}.{k}"])
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _pad_prompt(ids, plen):
    out = np.full(plen, ml.TOK["PAD"], np.int32)
    out[: len(ids)] = ids
    return out


def spec(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


class Exporter:
    def __init__(self, outdir: pathlib.Path, force: bool, log=print):
        self.outdir = outdir
        self.force = force
        self.log = log
        self.entries = []

    def export(self, name: str, fn, example_args, *, model, variant, phase,
               batch, weights_key, state_len):
        rel = f"exe/{name}.hlo.txt"
        path = self.outdir / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        if self.force or not path.exists():
            t0 = time.time()
            text = lower_to_hlo_text(fn, example_args)
            path.write_text(text)
            self.log(f"  [aot] {name}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s")
        self.entries.append({
            "name": name,
            "model": model,
            "variant": variant,
            "phase": phase,
            "batch": batch,
            "hlo": rel,
            "weights": weights_key,
            "state_len": state_len,
        })


# ---------------------------------------------------------------------------
# Main pipeline
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="legacy sentinel path (Makefile stamp); implies artifacts dir = its parent")
    ap.add_argument("--artifacts", default=None, help="artifacts directory")
    ap.add_argument("--force", action="store_true", help="rebuild everything")
    ap.add_argument("--force-export", action="store_true", help="re-export graphs")
    ap.add_argument("--fast", action="store_true",
                    help="tiny training budget (CI smoke; accuracy will be poor)")
    args = ap.parse_args()

    if args.artifacts:
        outdir = pathlib.Path(args.artifacts)
    elif args.out:
        outdir = pathlib.Path(args.out).parent
    else:
        outdir = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "datasets").mkdir(exist_ok=True)
    (outdir / "weights").mkdir(exist_ok=True)
    (outdir / "exe").mkdir(exist_ok=True)
    log = print

    # ------------------------------------------------------------------ 1
    log("[aot] 1/6 benchmarks + corpus")
    # Difficulty bands mirror the paper's score ordering (HumanEval scores
    # run higher than MBPP for openPangu): HumanEval-S is mostly 1-op,
    # MBPP-S is mostly 2-op compositions.
    he = taskgen.make_benchmark("humaneval_s", 164, 1, 2,
                                BENCH_SEEDS["humaneval_s"], p_long=0.25)
    mb = taskgen.make_benchmark("mbpp_s", 257, 1, 2, BENCH_SEEDS["mbpp_s"],
                                exclude=he["sigs"], p_long=0.6)
    for bench in (he, mb):
        p = outdir / "datasets" / f"{bench['name']}.json"
        if args.force or not p.exists():
            p.write_text(json.dumps(taskgen.benchmark_json(bench)))
            log(f"  wrote {p.name}: {len(bench['tasks'])} tasks")
    exclude = he["sigs"] | mb["sigs"]
    stream = taskgen.training_stream(TRAIN_STREAM_SEED, exclude, n=30000)

    # ------------------------------------------------------------------ 2
    log("[aot] 2/6 training")
    params_by_model = {}
    train_meta = {}
    for mname, plan in TRAIN_PLAN.items():
        cfg = M.CONFIGS[mname]
        wpath = outdir / f"params_{mname}.npz"
        if not args.force and wpath.exists():
            params_by_model[mname] = load_params(wpath, cfg)
            log(f"  {mname}: cached ({wpath.name})")
            continue
        plan = dict(plan)
        if args.fast:
            plan["steps"] = 40
        res = T.train(cfg, stream, steps=plan["steps"], batch=plan["batch"],
                      peak_lr=plan["peak_lr"], seed=plan["seed"], log=log)
        save_params(wpath, res["params"])
        params_by_model[mname] = res["params"]
        train_meta[mname] = {
            "steps": plan["steps"],
            "batch": plan["batch"],
            "final_loss": res["losses"][-1],
            "seconds": round(res["seconds"], 1),
            "loss_curve": [round(x, 4) for x in res["losses"][:: max(1, plan["steps"] // 50)]],
        }

    # ------------------------------------------------------------------ 3
    log("[aot] 3/6 calibration")
    calib_by_model = {}
    for mname, params in params_by_model.items():
        cfg = M.CONFIGS[mname]
        cpath = outdir / f"calib_{mname}.npz"
        if not args.force and cpath.exists():
            z = np.load(cpath)
            calib_by_model[mname] = {k: z[k] for k in z.files}
            log(f"  {mname}: cached")
            continue
        prompts = np.stack([
            _pad_prompt(ml.encode_prompt(t["mode"], t["examples"]), cfg.prompt_len)
            for t in stream[:CALIB_PROMPTS]
        ])
        stats = Q.calibrate(cfg, params, jnp.asarray(prompts))
        np.savez(cpath, **stats)
        calib_by_model[mname] = stats
        log(f"  {mname}: {len(stats)} linears calibrated")

    # ------------------------------------------------------------------ 4+5
    log("[aot] 4-5/6 quantize + export")
    ex = Exporter(outdir, args.force or args.force_export, log)
    weight_manifests = {}
    for mname, variants in MODEL_VARIANTS.items():
        cfg = M.CONFIGS[mname]
        params = params_by_model[mname]
        stats = calib_by_model[mname]
        for variant in variants:
            specs = Q.quantize(cfg, params, variant, stats)
            names, arrays, _ = M.flatten_specs(specs)
            wkey = f"{mname}_{variant}"
            wrel = f"weights/{wkey}.pten"
            wpath = outdir / wrel
            if args.force or not wpath.exists():
                write_pten(wpath, [(n, np.asarray(a)) for n, a in zip(names, arrays)])
            weight_manifests[wkey] = {
                "file": wrel,
                "tensors": [
                    {"name": n, "dtype": str(np.asarray(a).dtype),
                     "shape": list(np.asarray(a).shape)}
                    for n, a in zip(names, arrays)
                ],
            }

            buckets = set(SERVE_BUCKETS)
            if mname == "7b-sim" and variant in ("fp16", "int8"):
                buckets |= set(LATENCY_BUCKETS)
            for b in sorted(buckets):
                slen = M.state_len(cfg, b)
                arr_specs = [spec(np.asarray(a).shape, np.asarray(a).dtype)
                             for a in arrays]
                pf, _, _ = M.serve_prefill(cfg, specs)
                ex.export(
                    f"{wkey}_prefill_b{b}", pf,
                    (arr_specs, spec((b, cfg.prompt_len), jnp.int32),
                     spec((b,), jnp.int32)),
                    model=mname, variant=variant, phase="prefill", batch=b,
                    weights_key=wkey, state_len=slen,
                )
                if b in SERVE_BUCKETS:
                    df, _, _ = M.serve_decode(cfg, specs, b)
                    ex.export(
                        f"{wkey}_decode_b{b}", df,
                        (arr_specs, spec((b,), jnp.int32),
                         spec((slen,), jnp.float32), spec((b,), jnp.int32)),
                        model=mname, variant=variant, phase="decode", batch=b,
                        weights_key=wkey, state_len=slen,
                    )

        # readout is variant-independent: one per (model, bucket). Latency
        # buckets get one too so Table 3 timing forces completion the same
        # way at every batch size.
        ro_buckets = set(SERVE_BUCKETS)
        if mname == "7b-sim":
            ro_buckets |= set(LATENCY_BUCKETS)
        for b in sorted(ro_buckets):
            slen = M.state_len(cfg, b)
            ro = M.serve_readout(cfg, b)
            ex.export(
                f"{mname}_readout_b{b}", ro, (spec((slen,), jnp.float32),),
                model=mname, variant=None, phase="readout", batch=b,
                weights_key=None, state_len=slen,
            )

    # ------------------------------------------------------------------ 6
    log("[aot] 6/6 manifest + fig1")
    fig1 = {}
    for mname in MODEL_VARIANTS:
        if mname != "7b-sim":
            continue
        fig1 = Q.channel_distributions(
            M.CONFIGS[mname], params_by_model[mname], calib_by_model[mname],
            layer=0, linear="wg",
        )
    (outdir / "fig1_channels.json").write_text(json.dumps(fig1))

    manifest = {
        "version": 1,
        "vocab": ml.VOCAB,
        "minilang": {"mod": ml.MOD, "seq_len": ml.SEQ_LEN, "ops": ml.OP_NAMES},
        "seq": {"prompt_len": ml.PROMPT_LEN, "max_seq": ml.MAX_SEQ,
                "train_seq": T.TRAIN_SEQ},
        "models": {
            name: {
                "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                "head_dim": cfg.head_dim, "vocab": cfg.vocab,
                "params": cfg.params_count(),
            }
            for name, cfg in M.CONFIGS.items()
        },
        "variants": MODEL_VARIANTS,
        "buckets": {"serve": SERVE_BUCKETS, "latency": LATENCY_BUCKETS},
        "executables": ex.entries,
        "weights": weight_manifests,
        "datasets": {
            "humaneval_s": "datasets/humaneval_s.json",
            "mbpp_s": "datasets/mbpp_s.json",
        },
        "fig1": "fig1_channels.json",
        "training": train_meta,
    }
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # Stamp file so the Makefile dependency works.
    if args.out:
        pathlib.Path(args.out).write_text("ok\n")
    log(f"[aot] done: {len(ex.entries)} executables in {outdir}")


if __name__ == "__main__":
    main()
