"""Artifact I/O: the PTEN binary tensor format and HLO text dumping.

PTEN is the weight interchange format between the Python compile path and
the Rust runtime (weights are runtime inputs, not HLO constants — see
DESIGN.md §3). Layout (little-endian):

    magic   b"PTEN\\x01"
    u32     n_tensors
    per tensor:
        u16     name_len, name (utf-8)
        u8      dtype   (0 = f32, 1 = i8, 2 = i32)
        u8      ndim
        u32[ndim] dims
        u64     nbytes
        bytes   raw data (C order, little-endian)

Mirrored by rust/src/runtime/weights.rs.
"""

from __future__ import annotations

import struct

import jax
import numpy as np
from jax._src.lib import xla_client as xc

MAGIC = b"PTEN\x01"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}


def write_pten(path, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_pten(path) -> list[tuple[str, np.ndarray]]:
    """Python-side reader (round-trip tests)."""
    inv = {v: k for k, v in DTYPES.items()}
    out = []
    with open(path, "rb") as f:
        assert f.read(5) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode()
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            arr = np.frombuffer(f.read(nbytes), dtype=inv[dt]).reshape(dims)
            out.append((name, arr))
    return out


def lower_to_hlo_text(fn, example_args) -> str:
    """jax fn -> HLO text via StableHLO (the 0.5.1-compatible interchange;
    see /opt/xla-example/README.md gotchas). return_tuple=False: all our
    serving graphs have exactly one (flat) output."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()
