"""MiniLang: the synthetic program-induction substrate.

The paper evaluates openPangu-Embedded on HumanEval/MBPP with
execution-based scoring. We cannot run a 7B code model here, so the
reproduction substitutes MiniLang: a tiny stack-program DSL over fixed-length
integer sequences. A task presents two input->output examples; the model must
emit the program (a short op sequence) that realises the transformation.
Scoring executes the emitted program on *held-out* test inputs (Rust VM at
serve time, `rust/src/bench_suite/vm.rs`; this module is the Python twin used
for data generation and as the ground-truth oracle in tests).

Values live in Z_16 (tokens DIGIT_0..DIGIT_15); sequences have fixed length
SEQ_LEN. Ops are closed over that domain, so every generated program is
executable and every execution is exact — the functional-correctness property
that HumanEval-style pass@1 scoring needs.
"""

from __future__ import annotations

MOD = 16          # value domain Z_16
SEQ_LEN = 5       # fixed sequence length for all tasks

# ---------------------------------------------------------------------------
# Instruction set. Names are vocabulary tokens; semantics are pure functions
# on tuples of ints in [0, MOD). Keep in sync with rust/src/bench_suite/vm.rs.
# ---------------------------------------------------------------------------

def _ew(f):
    return lambda xs: tuple(f(x) % MOD for x in xs)


OPS = {
    "ADD1":  _ew(lambda x: x + 1),
    "ADD2":  _ew(lambda x: x + 2),
    "SUB1":  _ew(lambda x: x - 1),
    "MUL2":  _ew(lambda x: x * 2),
    "NEG":   _ew(lambda x: -x),
    "REV":   lambda xs: tuple(reversed(xs)),
    "SORT":  lambda xs: tuple(sorted(xs)),
    "SORTD": lambda xs: tuple(sorted(xs, reverse=True)),
    "ROTL":  lambda xs: xs[1:] + xs[:1],
    "ROTR":  lambda xs: xs[-1:] + xs[:-1],
    "SWAP":  lambda xs: (xs[-1],) + xs[1:-1] + (xs[0],) if len(xs) >= 2 else xs,
    "CUMSUM": lambda xs: tuple(
        sum(xs[: i + 1]) % MOD for i in range(len(xs))
    ),
}

OP_NAMES = sorted(OPS)


def run_program(ops: list[str], xs: tuple[int, ...]) -> tuple[int, ...]:
    """Execute `ops` left-to-right on sequence `xs`."""
    for op in ops:
        xs = OPS[op](xs)
    return xs


def program_trace(ops: list[str], xs: tuple[int, ...]) -> list[tuple[str, tuple[int, ...]]]:
    """Intermediate states: [(op, state_after_op), ...]. This is the
    scratchpad content emitted in slow_think mode."""
    out = []
    for op in ops:
        xs = OPS[op](xs)
        out.append((op, xs))
    return out


# ---------------------------------------------------------------------------
# Vocabulary. Fixed order; baked into artifacts/manifest.json and mirrored by
# the Rust tokenizer. Vocab is padded to 64 (power of two keeps the unembed
# GEMM Hadamard-compatible).
# ---------------------------------------------------------------------------

SPECIAL = [
    "PAD", "BOS", "END",
    "MODE_NOTHINK", "MODE_AUTO", "MODE_SLOW",
    "IN", "OUT", "SEP", "ASK",
    "TRACE", "ENDTRACE", "STEP", "PROG",
]

DIGITS = [f"D{i}" for i in range(MOD)]

VOCAB = SPECIAL + DIGITS + OP_NAMES
VOCAB_SIZE = 64
assert len(VOCAB) <= VOCAB_SIZE, f"vocab overflow: {len(VOCAB)}"
VOCAB = VOCAB + [f"UNUSED{i}" for i in range(VOCAB_SIZE - len(VOCAB))]

TOK = {name: i for i, name in enumerate(VOCAB)}

MODE_TOKENS = {
    "no_think": TOK["MODE_NOTHINK"],
    "auto_think": TOK["MODE_AUTO"],
    "slow_think": TOK["MODE_SLOW"],
}

# Sequence budget (shared with the Rust serving stack via manifest.json).
PROMPT_LEN = 48    # prefill pad length: BOS MODE 3x(IN 5 OUT 5) 2xSEP ASK = 41
MAX_SEQ = 96       # KV capacity: prompt + longest slow_think completion


def encode_prompt(mode: str, examples: list[tuple[tuple[int, ...], tuple[int, ...]]]) -> list[int]:
    """Prompt layout: BOS MODE (IN xs OUT ys | SEP)* ASK."""
    ids = [TOK["BOS"], MODE_TOKENS[mode]]
    for i, (xs, ys) in enumerate(examples):
        if i > 0:
            ids.append(TOK["SEP"])
        ids.append(TOK["IN"])
        ids.extend(TOK[f"D{v}"] for v in xs)
        ids.append(TOK["OUT"])
        ids.extend(TOK[f"D{v}"] for v in ys)
    ids.append(TOK["ASK"])
    return ids


def encode_completion(mode: str, ops: list[str], first_input: tuple[int, ...],
                      hard: bool) -> list[int]:
    """Target completion for a training example.

    no_think  -> PROG ops END
    slow_think-> TRACE (STEP op state)* ENDTRACE PROG ops END
    auto_think-> slow format iff the task is hard, else no_think format.
    """
    with_trace = mode == "slow_think" or (mode == "auto_think" and hard)
    ids: list[int] = []
    if with_trace:
        ids.append(TOK["TRACE"])
        for op, state in program_trace(ops, first_input):
            ids.append(TOK["STEP"])
            ids.append(TOK[op])
            ids.extend(TOK[f"D{v}"] for v in state)
        ids.append(TOK["ENDTRACE"])
    ids.append(TOK["PROG"])
    ids.extend(TOK[op] for op in ops)
    ids.append(TOK["END"])
    return ids


def extract_program(token_ids: list[int]) -> list[str] | None:
    """Parse a generated completion back into a program: the op tokens
    between the last PROG marker and END. Returns None if malformed.
    Mirrored by rust bench_suite/scoring.rs."""
    names = [VOCAB[t] if 0 <= t < len(VOCAB) else "?" for t in token_ids]
    try:
        start = len(names) - 1 - names[::-1].index("PROG")
    except ValueError:
        return None
    ops = []
    for name in names[start + 1:]:
        if name == "END":
            return ops if ops else None
        if name not in OPS:
            return None
        ops.append(name)
    return None  # never hit END
