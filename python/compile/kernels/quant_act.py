"""Pallas per-token dynamic activation quantization.

x f32 [M, K] -> (xq int8 [M, K], scale f32 [M, 1]) with symmetric max-abs
scaling (paper Eq. 1-2, per-token granularity). Runs as the producer stage
immediately before the quantized GEMM kernels so that, in the lowered HLO,
quantize -> int GEMM -> dequant forms one conversion-free low-bit region.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT8_QMAX = 127.0
EPS = 1e-8


def _kernel(x_ref, xq_ref, s_ref):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, EPS) / INT8_QMAX
    q = jnp.clip(jnp.round(x / scale), -INT8_QMAX, INT8_QMAX)
    xq_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m",))
def quant_act(x, *, block_m: int = 128):
    """Per-token INT8 quantization of a [M, K] activation matrix."""
    m, k = x.shape
    bm = min(block_m, max(1, m))
    m_pad = pl.cdiv(m, bm) * bm
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))

    xq, s = pl.pallas_call(
        _kernel,
        grid=(m_pad // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, k), jnp.int8),
            jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        ],
        interpret=True,
    )(x)
    return xq[:m], s[:m]
