"""Pallas blocked Walsh–Hadamard transform (the QuaRot-style online rotation).

Computes x [M, D] -> x @ H with H the normalized Sylvester Hadamard matrix
(D a power of two). Instead of materializing H and paying an O(D^2) GEMM per
token, the kernel runs the O(D log D) butterfly in VMEM: log2(D) stages of
add/sub over a re-blocked view. This is the "promote a uniform distribution
before 4-bit quantization" preprocessing of paper Eq. 4, applied online to
activations (the weight-side H is folded offline by ref.fold_hadamard).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    x = x_ref[...]
    bm, d = x.shape
    h = 1
    # Sylvester-order FWHT butterfly; unrolled at trace time (d static).
    while h < d:
        y = x.reshape(bm, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(bm, d)
        h *= 2
    o_ref[...] = x * (1.0 / jnp.sqrt(jnp.float32(d)))


@functools.partial(jax.jit, static_argnames=("block_m",))
def hadamard(x, *, block_m: int = 128):
    """x f32 [M, D] -> x @ H, D a power of two."""
    m, d = x.shape
    assert d & (d - 1) == 0, f"D={d} must be a power of two"
    bm = min(block_m, max(1, m))
    m_pad = pl.cdiv(m, bm) * bm
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(m_pad // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), jnp.float32),
        interpret=True,
    )(x)
    return out[:m]
