"""Pure-jnp oracles for every Pallas kernel and quantization primitive.

These are the correctness ground truth: pytest/hypothesis sweeps assert the
Pallas kernels (interpret=True) match these bit-for-bit (integer paths) or to
float tolerance (dequant epilogues). They are also used directly by the
calibration pipeline, where kernel-grade performance is irrelevant.

Quantization follows the paper's formulation (Sec. 2): symmetric, scale from
max-abs, per-output-channel for weights, per-token for activations.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax
import numpy as np

INT8_QMAX = 127
INT4_QMAX = 7
EPS = 1e-8


# ---------------------------------------------------------------------------
# Weight quantization (offline, per output channel)
# ---------------------------------------------------------------------------

def quant_weight_int8(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-output-channel INT8 weight quantization.

    w: [K, N] float. Returns (wq int8 [K, N], scale f32 [1, N]) with
    dequant(wq) = wq * scale ≈ w.
    """
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(amax, EPS) / INT8_QMAX
    wq = jnp.clip(jnp.round(w / scale), -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return wq, scale.astype(jnp.float32)


def quant_weight_int4(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-output-channel INT4 weight quantization (values in
    [-7, 7], stored unpacked as int8). Packing is a separate, lossless step."""
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(amax, EPS) / INT4_QMAX
    wq = jnp.clip(jnp.round(w / scale), -INT4_QMAX, INT4_QMAX).astype(jnp.int8)
    return wq, scale.astype(jnp.float32)


def quant_weight_int4_grouped(w: jnp.ndarray, group: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Group-wise INT4: one scale per (group of `group` input rows, output
    channel). w: [K, N] with K % group == 0. Returns (wq [K,N], scale
    [K/group, N])."""
    k, n = w.shape
    assert k % group == 0, f"K={k} not divisible by group={group}"
    wg = w.reshape(k // group, group, n)
    amax = jnp.max(jnp.abs(wg), axis=1, keepdims=True)
    scale = jnp.maximum(amax, EPS) / INT4_QMAX
    wq = jnp.clip(jnp.round(wg / scale), -INT4_QMAX, INT4_QMAX)
    return wq.reshape(k, n).astype(jnp.int8), scale[:, 0, :].astype(jnp.float32)


# ---------------------------------------------------------------------------
# INT4 packing: two signed nibbles per int8 byte, along the K axis.
# byte i holds w[2i] (low nibble) and w[2i+1] (high nibble).
# ---------------------------------------------------------------------------

def pack_int4(wq: jnp.ndarray) -> jnp.ndarray:
    """wq: int8 [K, N] with values in [-8, 7] -> packed int8 [K//2, N]."""
    k = wq.shape[0]
    assert k % 2 == 0, "K must be even to pack"
    lo = wq[0::2].astype(jnp.int32) & 0xF
    hi = wq[1::2].astype(jnp.int32) & 0xF
    packed = lo | (hi << 4)
    # Values 0..255; reinterpret as int8 via uint8 wraparound.
    return packed.astype(jnp.uint8).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_int4: int8 [K//2, N] -> int8 [K, N] (sign-extended)."""
    p = packed.astype(jnp.uint8).astype(jnp.int32)
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    k2, n = packed.shape
    out = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
    return out.astype(jnp.int8)


# ---------------------------------------------------------------------------
# Activation quantization (dynamic, per token)
# ---------------------------------------------------------------------------

def quant_act(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token symmetric INT8: x [M, K] -> (xq int8 [M, K], scale [M, 1])."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, EPS) / INT8_QMAX
    xq = jnp.clip(jnp.round(x / scale), -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return xq, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Quantized GEMMs
# ---------------------------------------------------------------------------

def w8a8_matmul(xq, xs, wq, ws):
    """INT8 x INT8 -> INT32 GEMM with fused dequant epilogue.

    xq int8 [M, K], xs f32 [M, 1], wq int8 [K, N], ws f32 [1, N]
    -> f32 [M, N] = (xq @ wq) * xs * ws.
    """
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * xs * ws


def w4a8_matmul(xq, xs, packed, ws):
    """W4A8 GEMM: unpack int4 weights, int8 activations, int32 accumulate.

    xq int8 [M, K], xs f32 [M, 1], packed int8 [K//2, N], ws f32 [1, N].
    """
    wq = unpack_int4(packed)
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * xs * ws


# ---------------------------------------------------------------------------
# Hadamard rotation
# ---------------------------------------------------------------------------

def hadamard_matrix(d: int) -> np.ndarray:
    """Normalized Sylvester Hadamard matrix H (d a power of two), H H^T = I."""
    assert d & (d - 1) == 0 and d > 0, f"d={d} not a power of two"
    h = np.array([[1.0]])
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(d)).astype(np.float32)


def hadamard(x: jnp.ndarray) -> jnp.ndarray:
    """Reference rotation: x [..., D] @ H (H symmetric, normalized)."""
    h = jnp.asarray(hadamard_matrix(x.shape[-1]))
    return x @ h


# ---------------------------------------------------------------------------
# Preprocessing transforms (offline folding)
# ---------------------------------------------------------------------------

def smooth_scales(act_amax: jnp.ndarray, w: jnp.ndarray, alpha: float = 0.5):
    """SmoothQuant (Eq. 3): s_j = max|X_j|^a / max|W_j|^(1-a) per input
    channel j. Returns s [K] (applied as X' = X / s, W' = s * W)."""
    w_amax = jnp.max(jnp.abs(w), axis=1)
    s = jnp.power(jnp.maximum(act_amax, EPS), alpha) / jnp.power(
        jnp.maximum(w_amax, EPS), 1.0 - alpha
    )
    # Guard against extreme scales blowing up either side.
    return jnp.clip(s, 1e-2, 1e2).astype(jnp.float32)


def fold_smooth(w: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """W' = diag(s) W (rows scaled by s)."""
    return w * s[:, None]


def fold_hadamard(w: jnp.ndarray) -> jnp.ndarray:
    """Fold the rotation into the weight (Eq. 4): W' = H^T W (H symmetric =>
    H W). Runtime computes (X H) @ W'."""
    h = jnp.asarray(hadamard_matrix(w.shape[0]))
    return h @ w
