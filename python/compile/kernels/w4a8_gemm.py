"""Pallas W4A8 GEMM: packed INT4 weights x INT8 activations -> INT32.

Weights stay packed (two signed nibbles per byte, along K) in HBM and are
unpacked *inside* the kernel after the HBM->VMEM copy — halving weight-side
memory traffic exactly as the Atlas A2 CATLASS int4 path does, which is the
whole point of W4A8 (the paper's "extreme compression" configuration).
Activation path and dequant epilogue are identical to the W8A8 kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack(p: jnp.ndarray) -> jnp.ndarray:
    """int8 [K//2, bn] packed -> int32 [K, bn] sign-extended nibbles.
    byte i holds w[2i] (low) and w[2i+1] (high)."""
    v = p.astype(jnp.uint8).astype(jnp.int32)
    lo = ((v & 0xF) ^ 8) - 8
    hi = (((v >> 4) & 0xF) ^ 8) - 8
    k2, bn = p.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, bn)


def _kernel(xq_ref, xs_ref, wp_ref, ws_ref, o_ref):
    wq = _unpack(wp_ref[...])
    acc = jax.lax.dot_general(
        xq_ref[...].astype(jnp.int32),
        wq,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] = acc.astype(jnp.float32) * xs_ref[...] * ws_ref[...]


def _pad_rows(a: jnp.ndarray, to: int) -> jnp.ndarray:
    m = a.shape[0]
    return a if m == to else jnp.pad(a, ((0, to - m),) + ((0, 0),) * (a.ndim - 1))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def w4a8_gemm(xq, xs, packed, ws, *, block_m: int = 128, block_n: int = 128):
    """Quantized GEMM over packed int4 weights.

    xq: int8 [M, K], xs: f32 [M, 1]
    packed: int8 [K//2, N] (pack_int4 layout), ws: f32 [1, N]
    returns f32 [M, N]
    """
    m, k = xq.shape
    k2, n = packed.shape
    assert k == 2 * k2, f"K mismatch: activations {k}, packed {2 * k2}"
    bm = min(block_m, max(1, m))
    bn = min(block_n, n)
    m_pad = pl.cdiv(m, bm) * bm
    assert n % bn == 0, f"N={n} must be a multiple of block_n={bn}"

    xq_p = _pad_rows(xq, m_pad)
    xs_p = _pad_rows(xs, m_pad)

    out = pl.pallas_call(
        _kernel,
        grid=(m_pad // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k2, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=True,
    )(xq_p, xs_p, packed, ws)
    return out[:m]
