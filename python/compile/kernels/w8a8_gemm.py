"""Pallas W8A8 GEMM: INT8 x INT8 -> INT32 with fused dequant epilogue.

The paper's framework property (Sec. 3.1) is *native end-to-end low-bit
execution without intermediate format conversion*: on the Atlas A2 this is a
CATLASS template wiring int8 weight layout + dequant + cube-unit matmul into
one operator. The TPU-style rethink (DESIGN.md §Hardware adaptation):

  * grid tiles over (M, N); each program instance owns a [bm, bn] output
    block — the HBM<->VMEM schedule the NPU version expresses with its
    L1/UB tiling;
  * the full K reduction stays resident in VMEM (K <= 512 for every linear
    in the model, so an [bm, K] int8 slab + [K, bn] weight slab fit easily);
  * int32 accumulation via an MXU-shaped dot_general, dequant (per-token x
    per-channel scales) fused into the epilogue of the same kernel, so no
    int32 tensor ever round-trips through HBM.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic custom
calls; real-TPU performance is estimated analytically (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xq_ref, xs_ref, wq_ref, ws_ref, o_ref):
    # [bm, K] i8 . [K, bn] i8 -> [bm, bn] i32, then fused dequant epilogue.
    acc = jax.lax.dot_general(
        xq_ref[...],
        wq_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] = acc.astype(jnp.float32) * xs_ref[...] * ws_ref[...]


def _pad_rows(a: jnp.ndarray, to: int) -> jnp.ndarray:
    m = a.shape[0]
    return a if m == to else jnp.pad(a, ((0, to - m),) + ((0, 0),) * (a.ndim - 1))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def w8a8_gemm(xq, xs, wq, ws, *, block_m: int = 128, block_n: int = 128):
    """Quantized GEMM.

    xq: int8 [M, K]   per-token-quantized activations
    xs: f32  [M, 1]   per-token activation scales
    wq: int8 [K, N]   per-channel-quantized weights
    ws: f32  [1, N]   per-channel weight scales
    returns f32 [M, N] ≈ dequant(xq) @ dequant(wq)
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2, f"K mismatch {k} vs {k2}"
    bm = min(block_m, max(1, m))
    bn = min(block_n, n)
    m_pad = pl.cdiv(m, bm) * bm
    n_pad = pl.cdiv(n, bn) * bn
    assert n_pad == n, f"N={n} must be a multiple of block_n={bn}"

    xq_p = _pad_rows(xq, m_pad)
    xs_p = _pad_rows(xs, m_pad)

    out = pl.pallas_call(
        _kernel,
        grid=(m_pad // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=True,
    )(xq_p, xs_p, wq, ws)
    return out[:m]
