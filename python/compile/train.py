"""Build-time training of the pangu-lite models on the MiniLang corpus.

The real openPangu-Embedded checkpoints are proprietary; the reproduction
trains its simulated scales from scratch (DESIGN.md §2). Training is a plain
next-token cross-entropy run over rendered (prompt, completion) pairs with
all three CoT directives mixed into the stream, so the modes are selectable
at inference time by the prompt directive alone — the paper's mechanism.

No optax in this environment: AdamW + cosine schedule are implemented here.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import minilang as ml
from . import model as M
from . import taskgen

TRAIN_SEQ = 72  # prompt (41) + longest slow_think completion (<= 24) + slack

# Extra loss weight on the program tokens (PROG op... END): the trace and
# format tokens dominate raw token counts, but task success depends on the
# program — weighting sharpens induction without changing the data.
PROGRAM_LOSS_WEIGHT = 3.0


# ---------------------------------------------------------------------------
# Data rendering
# ---------------------------------------------------------------------------


def render_batch(stream: list[dict], start: int, batch: int):
    """-> (tokens int32 [B, TRAIN_SEQ], loss_mask f32 [B, TRAIN_SEQ]).

    tokens = prompt ++ completion ++ PAD; the mask selects positions whose
    *target* (next token) lies in the completion."""
    toks = np.zeros((batch, TRAIN_SEQ), np.int32)
    mask = np.zeros((batch, TRAIN_SEQ), np.float32)
    for i in range(batch):
        task = stream[(start + i) % len(stream)]
        prompt, completion = taskgen.render_training_example(task)
        seq = (prompt + completion)[:TRAIN_SEQ]
        toks[i, : len(seq)] = seq
        # Position j predicts token j+1: completion tokens occupy
        # [len(prompt), len(seq)), so mask predictor positions
        # [len(prompt)-1, len(seq)-1).
        mask[i, len(prompt) - 1 : len(seq) - 1] = 1.0
        # Upweight predictions of the program segment (PROG onwards).
        prog_at = completion.index(ml.TOK["PROG"])
        mask[i, len(prompt) + prog_at - 1 : len(seq) - 1] = PROGRAM_LOSS_WEIGHT
    return jnp.asarray(toks), jnp.asarray(mask)


# ---------------------------------------------------------------------------
# Loss / optimizer
# ---------------------------------------------------------------------------


def loss_fn(params, cfg, tokens, mask):
    logits = M.forward_seq(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, :-1]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - lr * (step + wd * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def lr_schedule(step, total, peak):
    warmup = max(1, total // 20)
    w = jnp.minimum((step + 1.0) / warmup, 1.0)
    progress = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return peak * w * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


@functools.partial(jax.jit, static_argnames=("cfg", "total", "peak"))
def train_step(params, opt, tokens, mask, step, *, cfg, total, peak):
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens, mask)
    grads, _ = clip_by_global_norm(grads, 1.0)
    lr = lr_schedule(step.astype(jnp.float32), total, peak)
    params, opt = adamw_update(params, grads, opt, lr)
    return params, opt, loss


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def train(cfg: M.ModelConfig, stream: list[dict], *, steps: int, batch: int,
          peak_lr: float = 3e-3, seed: int = 0, log_every: int = 50,
          log=print) -> dict:
    params = M.init_params(cfg, seed)
    opt = adamw_init(params)
    t0 = time.time()
    losses = []
    for step in range(steps):
        tokens, mask = render_batch(stream, step * batch, batch)
        params, opt, loss = train_step(
            params, opt, tokens, mask, jnp.asarray(step), cfg=cfg,
            total=steps, peak=peak_lr,
        )
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            log(f"[train:{cfg.name}] step {step:4d}/{steps} "
                f"loss {float(loss):.4f}  ({time.time() - t0:.0f}s)")
    return {"params": params, "losses": losses, "seconds": time.time() - t0}


# ---------------------------------------------------------------------------
# Greedy evaluation (Python twin of the Rust engine, for tests/reporting)
# ---------------------------------------------------------------------------


def greedy_generate(cfg: M.ModelConfig, specs: dict, prompt_ids: list[int],
                    max_new: int = 64) -> list[int]:
    """Single-sequence greedy decode through prefill_fn/decode_fn."""
    s = cfg.prompt_len
    toks = np.full((1, s), ml.TOK["PAD"], np.int32)
    toks[0, : len(prompt_ids)] = prompt_ids
    true_lens = jnp.asarray([len(prompt_ids)], jnp.int32)
    logits, kv = M.prefill_fn(cfg, specs, jnp.asarray(toks), true_lens)
    out = []
    pos = len(prompt_ids)
    tok = int(jnp.argmax(logits[0]))
    for _ in range(max_new):
        out.append(tok)
        if tok == ml.TOK["END"] or pos >= cfg.max_seq - 1:
            break
        logits, kv = M.decode_fn(
            cfg, specs, jnp.asarray([tok], jnp.int32), kv,
            jnp.asarray([pos], jnp.int32),
        )
        tok = int(jnp.argmax(logits[0]))
        pos += 1
    return out


def eval_accuracy(cfg: M.ModelConfig, specs: dict, tasks: list[dict],
                  mode: str, max_new: int = 64) -> float:
    """pass@1 over tasks: generated program must satisfy all held-out tests."""
    n_pass = 0
    for task in tasks:
        prompt = ml.encode_prompt(mode, task["examples"])
        gen = greedy_generate(cfg, specs, prompt, max_new)
        ops = ml.extract_program(gen)
        if ops is None:
            continue
        ok = all(ml.run_program(ops, tuple(i)) == tuple(o) for i, o in task["tests"])
        n_pass += ok
    return n_pass / max(1, len(tasks))
