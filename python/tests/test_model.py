"""L2 model tests: shapes, prefill/decode consistency, quantized divergence."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import minilang as ml
from compile import model as M
from compile import quantlib as Q

CFG = M.ModelConfig("test", d_model=64, n_layers=2, n_heads=2, d_ff=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=3)


@pytest.fixture(scope="module")
def fp(params):
    return M.fp_specs(params)


def _prompt_batch(b, plen, lens):
    rng = np.random.default_rng(0)
    toks = np.full((b, plen), ml.TOK["PAD"], np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(3, 40, size=l)
        toks[i, 0] = ml.TOK["BOS"]
    return jnp.asarray(toks), jnp.asarray(np.array(lens, np.int32))


# ---------------------------------------------------------------------------
# Shapes / basics
# ---------------------------------------------------------------------------


def test_param_count_formula(params):
    total = np.asarray(params["embed"]).size + np.asarray(params["lnf"]).size
    for layer in params["layers"]:
        total += sum(np.asarray(v).size for v in layer.values())
    assert total == CFG.params_count()


def test_forward_seq_shapes(params):
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 64, size=(3, 10), dtype=np.int32))
    logits = M.forward_seq(CFG, params, toks)
    assert logits.shape == (3, 10, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_shapes(fp):
    toks, lens = _prompt_batch(4, CFG.prompt_len, [5, 9, 27, 12])
    logits, kv = M.prefill_fn(CFG, fp, toks, lens)
    assert logits.shape == (4, CFG.vocab)
    assert kv.shape == M.kv_shape(CFG, 4)


# ---------------------------------------------------------------------------
# Consistency: prefill/decode must agree with full-sequence forward
# ---------------------------------------------------------------------------


def test_prefill_matches_forward_seq(params, fp):
    prompt = [ml.TOK["BOS"], 17, 22, 9, 30, 8]
    toks = np.full((1, CFG.prompt_len), ml.TOK["PAD"], np.int32)
    toks[0, : len(prompt)] = prompt
    lg, _ = M.prefill_fn(CFG, fp, jnp.asarray(toks),
                         jnp.asarray([len(prompt)], np.int32))
    full = M.forward_seq(CFG, params, jnp.asarray(np.array(prompt, np.int32)[None]))
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(full[0, -1]),
                               rtol=1e-4, atol=1e-5)


def test_decode_chain_matches_forward_seq(params, fp):
    # Prefill a prompt then decode 4 fixed tokens; logits at each step must
    # match the full-sequence forward pass on the growing sequence.
    prompt = [ml.TOK["BOS"], 20, 21, 22, 23]
    extra = [24, 25, 26, 27]
    toks = np.full((1, CFG.prompt_len), ml.TOK["PAD"], np.int32)
    toks[0, : len(prompt)] = prompt
    lg, kv = M.prefill_fn(CFG, fp, jnp.asarray(toks),
                          jnp.asarray([len(prompt)], np.int32))
    pos = len(prompt)
    seq = list(prompt)
    for t in extra:
        seq.append(t)
        lg, kv = M.decode_fn(CFG, fp, jnp.asarray([t], np.int32), kv,
                             jnp.asarray([pos], np.int32))
        full = M.forward_seq(CFG, params, jnp.asarray(np.array(seq, np.int32)[None]))
        np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(full[0, -1]),
                                   rtol=1e-3, atol=1e-4)
        pos += 1


def test_batched_decode_with_mixed_positions(params, fp):
    # Two sequences at different positions must evolve independently —
    # the property continuous batching relies on.
    p1 = [ml.TOK["BOS"], 10, 11]
    p2 = [ml.TOK["BOS"], 30, 31, 32, 33, 34]
    toks = np.full((2, CFG.prompt_len), ml.TOK["PAD"], np.int32)
    toks[0, : len(p1)] = p1
    toks[1, : len(p2)] = p2
    lens = jnp.asarray([len(p1), len(p2)], np.int32)
    _, kv = M.prefill_fn(CFG, fp, jnp.asarray(toks), lens)
    lg, _ = M.decode_fn(CFG, fp, jnp.asarray([40, 41], np.int32), kv,
                        jnp.asarray([len(p1), len(p2)], np.int32))
    # Row 0 must equal the single-sequence result for p1 + [40].
    toks1 = np.full((1, CFG.prompt_len), ml.TOK["PAD"], np.int32)
    toks1[0, : len(p1)] = p1
    _, kv1 = M.prefill_fn(CFG, fp, jnp.asarray(toks1),
                          jnp.asarray([len(p1)], np.int32))
    lg1, _ = M.decode_fn(CFG, fp, jnp.asarray([40], np.int32), kv1,
                         jnp.asarray([len(p1)], np.int32))
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(lg1[0]),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Quantized variants
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def calib(params):
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, 64, size=(8, 16), dtype=np.int32))
    return Q.calibrate(CFG, params, toks)


@pytest.mark.parametrize("variant", ["int8", "w4a8", "w4a8_smooth", "w4a8_hadamard"])
def test_quantized_prefill_close_to_fp(params, fp, calib, variant):
    specs = Q.quantize(CFG, params, variant, calib)
    toks, lens = _prompt_batch(2, CFG.prompt_len, [6, 11])
    lg_fp, _ = M.prefill_fn(CFG, fp, toks, lens)
    lg_q, _ = M.prefill_fn(CFG, specs, toks, lens)
    # Random-init weights: logits are O(1); divergence must stay bounded.
    diff = np.abs(np.asarray(lg_q) - np.asarray(lg_fp)).max()
    limit = 0.2 if variant == "int8" else 1.5
    assert diff < limit, f"{variant} diverged: {diff}"


def test_int8_beats_w4a8_on_logits(params, fp, calib):
    toks, lens = _prompt_batch(4, CFG.prompt_len, [6, 11, 20, 9])
    lg_fp, _ = M.prefill_fn(CFG, fp, toks, lens)

    def err(variant):
        sp = Q.quantize(CFG, params, variant, calib)
        lg, _ = M.prefill_fn(CFG, sp, toks, lens)
        return np.linalg.norm(np.asarray(lg) - np.asarray(lg_fp))

    assert err("int8") < err("w4a8")


def test_state_len_formula():
    assert M.state_len(CFG, 4) == 4 * CFG.vocab + int(np.prod(M.kv_shape(CFG, 4)))


# ---------------------------------------------------------------------------
# Spec flattening (the AOT weight ABI)
# ---------------------------------------------------------------------------


def test_flatten_specs_deterministic(fp):
    n1, a1, _ = M.flatten_specs(fp)
    n2, a2, _ = M.flatten_specs(fp)
    assert n1 == n2
    assert len(n1) == len(a1)


def test_flatten_rebuild_identity(params, calib):
    specs = Q.quantize(CFG, params, "w4a8_smooth", calib)
    names, arrays, rebuild = M.flatten_specs(specs)
    rebuilt = rebuild(arrays)
    n2, a2, _ = M.flatten_specs(rebuilt)
    assert names == n2
    for x, y in zip(arrays, a2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # static structure survives
    assert rebuilt["layers"][0]["wq"]["kind"] == "w4a8"


def test_flatten_names_unique(fp):
    names, _, _ = M.flatten_specs(fp)
    assert len(set(names)) == len(names)

