"""PTQ pipeline tests: calibration, folding, variant construction, Fig.1 data."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import quantlib as Q
from compile.kernels import ref

CFG = M.ModelConfig("test", d_model=64, n_layers=2, n_heads=2, d_ff=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=8)


@pytest.fixture(scope="module")
def calib(params):
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, 64, size=(12, 20), dtype=np.int32))
    return Q.calibrate(CFG, params, toks, batch=4)


def test_calibrate_covers_all_linears(calib):
    expected = {f"L{li}.{n}" for li in range(CFG.n_layers) for n in M.LINEAR_NAMES}
    assert set(calib) == expected
    for k, v in calib.items():
        dim = CFG.d_ff if k.endswith(".wd") else CFG.d_model
        assert v.shape == (dim,), k
        assert (v >= 0).all()


def test_calibrate_batch_invariance(params):
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, 64, size=(12, 20), dtype=np.int32))
    a = Q.calibrate(CFG, params, toks, batch=3)
    b = Q.calibrate(CFG, params, toks, batch=12)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


@pytest.mark.parametrize("variant", Q.VARIANTS)
def test_quantize_structure(params, calib, variant):
    specs = Q.quantize(CFG, params, variant, calib)
    assert len(specs["layers"]) == CFG.n_layers
    for layer in specs["layers"]:
        for name in M.LINEAR_NAMES:
            spec = layer[name]
            if variant == "fp16":
                assert spec["kind"] == "fp"
            elif variant == "int8":
                assert spec["kind"] == "int8"
                assert spec["wq"].dtype == jnp.int8
            else:
                assert spec["kind"] == "w4a8"
                k_in = M.linear_dims(CFG, name)[0]
                assert spec["wp"].shape[0] == k_in // 2
                assert spec.get("had", False) == (variant == "w4a8_hadamard")
                if variant == "w4a8_smooth":
                    assert "smooth_inv" in spec


def test_smooth_requires_calibration(params):
    with pytest.raises(ValueError):
        Q.quantize(CFG, params, "w4a8_smooth", None)


def test_unknown_variant_rejected(params):
    with pytest.raises(ValueError):
        Q.quantize(CFG, params, "int2", None)


def test_weight_error_ordering(params, calib):
    # Smooth/Hadamard must not hurt reconstruction vs raw W4A8 on an
    # outlier-heavy weight (the Table 2 mechanism at weight level).
    rng = np.random.default_rng(0)
    w = rng.normal(size=(128, 64)).astype(np.float32)
    w[5, :] *= 40.0  # hot input channel
    act = np.abs(rng.normal(size=(128,)).astype(np.float32)) + 0.1
    w = jnp.asarray(w)
    e_base = Q.weight_quant_error(w, "w4a8")
    e_had = Q.weight_quant_error(w, "w4a8_hadamard")
    e_int8 = Q.weight_quant_error(w, "int8")
    assert e_int8 < e_base
    assert e_had < e_base  # rotation spreads the hot channel


def test_channel_distributions_schema(params, calib):
    d = Q.channel_distributions(CFG, params, calib, layer=1, linear="wu")
    assert d["layer"] == 1 and d["linear"] == "wu"
    for key in ("weight_baseline", "weight_smooth", "weight_hadamard",
                "act_baseline", "act_smooth"):
        assert len(d[key]) == CFG.d_model
        assert all(v >= 0 for v in d[key])


def test_channel_distributions_smoothing_effect(params, calib):
    # After smoothing, the activation-side channel range must shrink
    # relative to baseline whenever outliers exist (Fig. 1's visual claim).
    d = Q.channel_distributions(CFG, params, calib, layer=0, linear="wg")
    base = np.array(d["act_baseline"])
    smooth = np.array(d["act_smooth"])
    assert smooth.max() / max(smooth.min(), 1e-6) <= base.max() / max(base.min(), 1e-6) * 1.01
