"""Kernel-vs-oracle correctness: the core L1 signal.

Every Pallas kernel (interpret=True) is swept against its pure-jnp oracle in
ref.py — exact equality on integer paths, float tolerance on dequant
epilogues. Hypothesis drives shape/value sweeps (test_kernel_properties.py
holds the heavier property sweeps; these are the deterministic fixtures).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.kernels.hadamard import hadamard
from compile.kernels.quant_act import quant_act
from compile.kernels.w4a8_gemm import w4a8_gemm
from compile.kernels.w8a8_gemm import w8a8_gemm


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


# ---------------------------------------------------------------------------
# quant_act
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k", [(1, 64), (7, 128), (128, 128), (130, 256), (256, 512)])
def test_quant_act_matches_ref(m, k):
    x = _rand((m, k), seed=m * 1000 + k)
    xq, xs = quant_act(x)
    xq_r, xs_r = ref.quant_act(x)
    np.testing.assert_array_equal(np.asarray(xq), np.asarray(xq_r))
    np.testing.assert_allclose(np.asarray(xs), np.asarray(xs_r), rtol=1e-6)


def test_quant_act_roundtrip_error_bound():
    # |dequant(quant(x)) - x| <= scale/2 per element (round-to-nearest).
    x = _rand((33, 128), seed=5, scale=3.0)
    xq, xs = quant_act(x)
    deq = np.asarray(xq, np.float32) * np.asarray(xs)
    err = np.abs(deq - np.asarray(x))
    bound = np.asarray(xs) / 2 + 1e-6
    assert (err <= bound).all()


def test_quant_act_zero_rows_safe():
    x = jnp.zeros((4, 64), jnp.float32)
    xq, xs = quant_act(x)
    assert np.asarray(xq).sum() == 0
    assert (np.asarray(xs) > 0).all()  # eps floor, no div-by-zero


def test_quant_act_extreme_values():
    x = jnp.asarray(np.array([[1e6, -1e6, 0.5, 0.0] * 16], np.float32))
    xq, _ = quant_act(x)
    assert np.asarray(xq).max() == 127
    assert np.asarray(xq).min() == -127


# ---------------------------------------------------------------------------
# w8a8 GEMM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(1, 64, 64), (8, 128, 128), (37, 128, 256),
                                   (128, 256, 128), (200, 256, 512)])
def test_w8a8_matches_ref(m, k, n):
    x = _rand((m, k), seed=m + k + n)
    w = _rand((k, n), seed=m * k + n)
    xq, xs = ref.quant_act(x)
    wq, ws = ref.quant_weight_int8(w)
    out = w8a8_gemm(xq, xs, wq, ws)
    out_r = ref.w8a8_matmul(xq, xs, wq, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=1e-5, atol=1e-6)


def test_w8a8_integer_accumulation_exact():
    # The int32 accumulator must be exact: compare against int64 numpy.
    rng = np.random.default_rng(0)
    xq = rng.integers(-127, 128, size=(16, 256), dtype=np.int8)
    wq = rng.integers(-127, 128, size=(256, 128), dtype=np.int8)
    xs = np.ones((16, 1), np.float32)
    ws = np.ones((1, 128), np.float32)
    out = np.asarray(w8a8_gemm(jnp.asarray(xq), jnp.asarray(xs),
                               jnp.asarray(wq), jnp.asarray(ws)))
    exact = xq.astype(np.int64) @ wq.astype(np.int64)
    np.testing.assert_array_equal(out.astype(np.int64), exact)


def test_w8a8_approximates_fp_gemm():
    x = _rand((32, 128), seed=1)
    w = _rand((128, 128), seed=2)
    xq, xs = ref.quant_act(x)
    wq, ws = ref.quant_weight_int8(w)
    out = np.asarray(w8a8_gemm(xq, xs, wq, ws))
    fp = np.asarray(x) @ np.asarray(w)
    rel = np.linalg.norm(out - fp) / np.linalg.norm(fp)
    assert rel < 0.02, f"int8 GEMM relative error {rel}"


# ---------------------------------------------------------------------------
# int4 packing + w4a8 GEMM
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip_all_values():
    # Every int4 value in every nibble position.
    vals = np.arange(-8, 8, dtype=np.int8)
    wq = jnp.asarray(np.stack([np.repeat(vals, 2), np.tile(vals, 2)], axis=1))
    packed = ref.pack_int4(wq)
    assert packed.shape == (16, 2)
    np.testing.assert_array_equal(np.asarray(ref.unpack_int4(packed)), np.asarray(wq))


@pytest.mark.parametrize("m,k,n", [(1, 64, 64), (9, 128, 128), (64, 256, 256)])
def test_w4a8_matches_ref(m, k, n):
    x = _rand((m, k), seed=m + 2 * k + n)
    w = _rand((k, n), seed=3 * m + k + n)
    xq, xs = ref.quant_act(x)
    wq, ws = ref.quant_weight_int4(w)
    packed = ref.pack_int4(wq)
    out = w4a8_gemm(xq, xs, packed, ws)
    out_r = ref.w4a8_matmul(xq, xs, packed, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=1e-5, atol=1e-6)


def test_w4a8_error_larger_than_w8a8():
    # The paper's central accuracy ordering, at the GEMM level.
    x = _rand((64, 256), seed=10)
    w = _rand((256, 256), seed=11)
    fp = np.asarray(x) @ np.asarray(w)
    xq, xs = ref.quant_act(x)
    wq8, ws8 = ref.quant_weight_int8(w)
    e8 = np.linalg.norm(np.asarray(w8a8_gemm(xq, xs, wq8, ws8)) - fp)
    wq4, ws4 = ref.quant_weight_int4(w)
    e4 = np.linalg.norm(np.asarray(w4a8_gemm(xq, xs, ref.pack_int4(wq4), ws4)) - fp)
    assert e4 > 2 * e8


# ---------------------------------------------------------------------------
# Hadamard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,d", [(1, 64), (5, 128), (33, 256), (128, 512)])
def test_hadamard_matches_ref(m, d):
    x = _rand((m, d), seed=m + d)
    out = hadamard(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.hadamard(x)),
                               rtol=1e-4, atol=1e-5)


def test_hadamard_orthogonal():
    # H H = I (normalized symmetric): double rotation restores input.
    x = _rand((16, 128), seed=42)
    np.testing.assert_allclose(np.asarray(hadamard(hadamard(x))), np.asarray(x),
                               rtol=1e-4, atol=1e-5)


def test_hadamard_preserves_norm():
    x = _rand((8, 256), seed=3)
    n0 = np.linalg.norm(np.asarray(x), axis=1)
    n1 = np.linalg.norm(np.asarray(hadamard(x)), axis=1)
    np.testing.assert_allclose(n0, n1, rtol=1e-5)


def test_hadamard_spreads_outliers():
    # A one-hot row (extreme outlier) becomes uniform magnitude — the
    # mechanism behind Fig. 1's smoothed distribution.
    x = np.zeros((1, 128), np.float32)
    x[0, 7] = 100.0
    out = np.asarray(hadamard(jnp.asarray(x)))
    assert np.allclose(np.abs(out), 100.0 / np.sqrt(128), atol=1e-4)


def test_hadamard_gemm_equivalence():
    # (X H)(H W) == X W in fp: the mathematical-equivalence claim of Eq. 4.
    x = _rand((16, 128), seed=6)
    w = _rand((128, 64), seed=7)
    y_rot = np.asarray(hadamard(x)) @ np.asarray(ref.fold_hadamard(w))
    y = np.asarray(x) @ np.asarray(w)
    np.testing.assert_allclose(y_rot, y, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# SmoothQuant folding
# ---------------------------------------------------------------------------


def test_smooth_fold_equivalence():
    # (X S^-1)(S W) == X W in fp: the mathematical-equivalence claim of Eq. 3.
    x = _rand((16, 128), seed=8)
    w = _rand((128, 64), seed=9)
    act_amax = jnp.max(jnp.abs(x), axis=0)
    s = ref.smooth_scales(act_amax, w, 0.5)
    y_s = (np.asarray(x) / np.asarray(s)) @ np.asarray(ref.fold_smooth(w, s))
    np.testing.assert_allclose(y_s, np.asarray(x) @ np.asarray(w), rtol=1e-3, atol=1e-4)


def test_smooth_reduces_act_range_on_outliers():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 128)).astype(np.float32)
    x[:, 3] *= 50.0  # outlier channel, as in Fig. 1 baseline
    w = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    act_amax = jnp.max(jnp.abs(jnp.asarray(x)), axis=0)
    s = np.asarray(ref.smooth_scales(act_amax, w, 0.5))
    smoothed = x / s
    assert np.abs(smoothed).max() < np.abs(x).max() / 3
