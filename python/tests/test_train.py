"""Training-loop unit tests (fast: tiny model, few steps)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import minilang as ml
from compile import model as M
from compile import taskgen
from compile import train as T

CFG = M.ModelConfig("tiny", d_model=64, n_layers=2, n_heads=2, d_ff=128)


@pytest.fixture(scope="module")
def stream():
    return taskgen.training_stream(seed=3, exclude=set(), n=400)


def test_render_batch_shapes_and_mask(stream):
    toks, mask = T.render_batch(stream, 0, 8)
    assert toks.shape == (8, T.TRAIN_SEQ)
    assert mask.shape == (8, T.TRAIN_SEQ)
    m = np.asarray(mask)
    t = np.asarray(toks)
    for i in range(8):
        nz = np.nonzero(m[i])[0]
        assert nz.size > 0
        # The last masked position predicts END.
        assert t[i, nz[-1] + 1] == ml.TOK["END"]
        # Mask starts exactly where the completion begins (position of the
        # token after ASK, minus one for next-token prediction).
        ask_pos = int(np.nonzero(t[i] == ml.TOK["ASK"])[0][0])
        assert nz[0] == ask_pos


def test_render_batch_wraps_stream(stream):
    toks1, _ = T.render_batch(stream, 0, 4)
    toks2, _ = T.render_batch(stream, len(stream), 4)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))


def test_loss_decreases():
    stream = taskgen.training_stream(seed=5, exclude=set(), n=600)
    res = T.train(CFG, stream, steps=30, batch=16, log=lambda *_: None)
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first * 0.8, f"loss did not improve: {first} -> {last}"


def test_adamw_moves_params():
    params = M.init_params(CFG, 0)
    opt = T.adamw_init(params)
    toks, mask = T.render_batch(taskgen.training_stream(seed=1, exclude=set(), n=32), 0, 8)
    new_params, _, loss = T.train_step(
        params, opt, toks, mask, jnp.asarray(0), cfg=CFG, total=10, peak=1e-3
    )
    assert float(loss) > 0
    moved = np.abs(np.asarray(new_params["embed"]) - np.asarray(params["embed"])).max()
    assert moved > 0


def test_lr_schedule_shape():
    total = 100
    lrs = [float(T.lr_schedule(jnp.asarray(float(s)), total, 1.0)) for s in range(total)]
    peak_at = int(np.argmax(lrs))
    assert peak_at <= total // 10          # warmup peaks early
    assert lrs[-1] < 0.2                    # decays
    assert max(lrs) <= 1.0 + 1e-6


def test_greedy_generate_terminates():
    params = M.init_params(CFG, 2)
    specs = M.fp_specs(params)
    t = taskgen.sample_task(__import__("random").Random(0), 1, 2)
    prompt = ml.encode_prompt("no_think", t["examples"])
    gen = T.greedy_generate(CFG, specs, prompt, max_new=16)
    assert 1 <= len(gen) <= 16


def test_eval_accuracy_random_model_near_zero():
    params = M.init_params(CFG, 4)
    specs = M.fp_specs(params)
    rng = __import__("random").Random(7)
    tasks = [taskgen.sample_task(rng, 1, 2) for _ in range(5)]
    acc = T.eval_accuracy(CFG, specs, tasks, "no_think", max_new=12)
    assert acc <= 0.4  # untrained model can't reliably solve tasks
