"""MiniLang DSL + taskgen unit tests (the benchmark substrate)."""

import random

import pytest
from hypothesis import given, settings, strategies as st

from compile import minilang as ml
from compile import taskgen


# ---------------------------------------------------------------------------
# DSL semantics
# ---------------------------------------------------------------------------


def test_op_closure():
    # Every op maps Z_16^5 -> Z_16^5.
    xs = (0, 5, 15, 8, 3)
    for name, fn in ml.OPS.items():
        out = fn(xs)
        assert len(out) == ml.SEQ_LEN, name
        assert all(0 <= v < ml.MOD for v in out), name


def test_specific_semantics():
    xs = (1, 2, 3, 4, 5)
    assert ml.OPS["ADD1"](xs) == (2, 3, 4, 5, 6)
    assert ml.OPS["SUB1"]((0, 1, 2, 3, 4)) == (15, 0, 1, 2, 3)  # wraps mod 16
    assert ml.OPS["MUL2"]((8, 1, 2, 3, 4)) == (0, 2, 4, 6, 8)   # 16 mod 16 = 0
    assert ml.OPS["NEG"]((0, 1, 15, 8, 2)) == (0, 15, 1, 8, 14)
    assert ml.OPS["REV"](xs) == (5, 4, 3, 2, 1)
    assert ml.OPS["SORT"]((3, 1, 2, 5, 4)) == (1, 2, 3, 4, 5)
    assert ml.OPS["SORTD"]((3, 1, 2, 5, 4)) == (5, 4, 3, 2, 1)
    assert ml.OPS["ROTL"](xs) == (2, 3, 4, 5, 1)
    assert ml.OPS["ROTR"](xs) == (5, 1, 2, 3, 4)
    assert ml.OPS["SWAP"](xs) == (5, 2, 3, 4, 1)
    assert ml.OPS["CUMSUM"]((1, 2, 3, 4, 5)) == (1, 3, 6, 10, 15)


def test_run_program_composition():
    xs = (1, 2, 3, 4, 5)
    assert ml.run_program(["ADD1", "REV"], xs) == tuple(reversed([2, 3, 4, 5, 6]))
    assert ml.run_program([], xs) == xs


def test_program_trace_states():
    tr = ml.program_trace(["ADD1", "MUL2"], (1, 2, 3, 4, 5))
    assert tr[0] == ("ADD1", (2, 3, 4, 5, 6))
    assert tr[1] == ("MUL2", (4, 6, 8, 10, 12))


# ---------------------------------------------------------------------------
# Vocabulary / encoding
# ---------------------------------------------------------------------------


def test_vocab_size_and_uniqueness():
    assert len(ml.VOCAB) == ml.VOCAB_SIZE == 64
    assert len(set(ml.VOCAB)) == 64


def test_prompt_encoding_fits_budget():
    rng = random.Random(0)
    for _ in range(50):
        t = taskgen.sample_task(rng, 1, 3)
        for mode in ml.MODE_TOKENS:
            ids = ml.encode_prompt(mode, t["examples"])
            assert len(ids) <= ml.PROMPT_LEN
            assert ids[0] == ml.TOK["BOS"]
            assert ids[-1] == ml.TOK["ASK"]


def test_completion_encoding_fits_budget():
    rng = random.Random(1)
    for _ in range(50):
        t = taskgen.sample_task(rng, 1, 3)
        for mode in ml.MODE_TOKENS:
            comp = ml.encode_completion(mode, t["program"],
                                        t["examples"][0][0], t["hard"])
            prompt = ml.encode_prompt(mode, t["examples"])
            assert len(prompt) + len(comp) <= ml.MAX_SEQ
            assert comp[-1] == ml.TOK["END"]


def test_completion_mode_structure():
    rng = random.Random(2)
    t = taskgen.sample_task(rng, 2, 3)  # hard task
    no = ml.encode_completion("no_think", t["program"], t["examples"][0][0], t["hard"])
    slow = ml.encode_completion("slow_think", t["program"], t["examples"][0][0], t["hard"])
    auto = ml.encode_completion("auto_think", t["program"], t["examples"][0][0], t["hard"])
    assert ml.TOK["TRACE"] not in no
    assert slow[0] == ml.TOK["TRACE"]
    assert auto == slow  # hard -> auto uses the trace
    easy = taskgen.sample_task(rng, 1, 1)
    auto_easy = ml.encode_completion("auto_think", easy["program"],
                                     easy["examples"][0][0], easy["hard"])
    assert ml.TOK["TRACE"] not in auto_easy  # easy -> no trace


def test_extract_program_roundtrip():
    comp = ml.encode_completion("slow_think", ["REV", "ADD1"], (1, 2, 3, 4, 5), True)
    assert ml.extract_program(comp) == ["REV", "ADD1"]
    comp2 = ml.encode_completion("no_think", ["SORT"], (1, 2, 3, 4, 5), False)
    assert ml.extract_program(comp2) == ["SORT"]


def test_extract_program_malformed():
    assert ml.extract_program([]) is None
    assert ml.extract_program([ml.TOK["PROG"]]) is None  # no END
    assert ml.extract_program([ml.TOK["PROG"], ml.TOK["END"]]) is None  # empty
    assert ml.extract_program([ml.TOK["PROG"], ml.TOK["IN"], ml.TOK["END"]]) is None
    assert ml.extract_program([ml.TOK["REV"], ml.TOK["END"]]) is None  # no PROG


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(ml.OP_NAMES), min_size=1, max_size=4),
       st.booleans())
def test_extract_inverts_encode(ops, hard):
    for mode in ("no_think", "slow_think", "auto_think"):
        comp = ml.encode_completion(mode, ops, (1, 2, 3, 4, 5), hard)
        assert ml.extract_program(comp) == ops


# ---------------------------------------------------------------------------
# taskgen
# ---------------------------------------------------------------------------


def test_benchmark_determinism_and_sizes():
    b1 = taskgen.make_benchmark("x", 20, 2, 3, seed=5)
    b2 = taskgen.make_benchmark("x", 20, 2, 3, seed=5)
    assert taskgen.benchmark_json(b1) == taskgen.benchmark_json(b2)
    assert len(b1["tasks"]) == 20


def test_benchmark_tests_consistent_with_program():
    b = taskgen.make_benchmark("x", 30, 1, 3, seed=6)
    for t in b["tasks"]:
        for xs, ys in t["examples"] + t["tests"]:
            assert ml.run_program(t["program"], tuple(xs)) == tuple(ys)


def test_benchmark_difficulty_bands():
    he = taskgen.make_benchmark("he", 30, 2, 3, seed=7)
    mb = taskgen.make_benchmark("mb", 30, 1, 2, seed=8)
    assert all(len(t["program"]) >= 2 for t in he["tasks"])
    assert all(len(t["program"]) <= 2 for t in mb["tasks"])


def test_training_stream_excludes_benchmarks():
    he = taskgen.make_benchmark("he", 50, 1, 3, seed=9)
    stream = taskgen.training_stream(seed=10, exclude=he["sigs"], n=200)
    sigs = {taskgen._signature(t) for t in stream}
    assert not (sigs & he["sigs"])


def test_training_stream_mode_mix():
    stream = taskgen.training_stream(seed=11, exclude=set(), n=600)
    modes = {m: sum(t["mode"] == m for t in stream) for m in
             ("no_think", "auto_think", "slow_think")}
    for m, c in modes.items():
        assert c > 120, f"mode {m} underrepresented: {c}"
