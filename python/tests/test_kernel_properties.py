"""Hypothesis property sweeps over the Pallas kernels (shapes/dtypes/values).

The system prompt for this reproduction mandates hypothesis-driven sweeps of
the kernel surface: arbitrary (m, k, n) within the model's envelope, adversarial
value distributions (outliers, zeros, denormal-ish), asserting allclose against
ref.py every time.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hadamard import hadamard
from compile.kernels.quant_act import quant_act
from compile.kernels.w4a8_gemm import w4a8_gemm
from compile.kernels.w8a8_gemm import w8a8_gemm

# Model envelope: K, N are multiples of 64 up to 512; M arbitrary small.
dims_mk = st.tuples(
    st.integers(min_value=1, max_value=160),
    st.sampled_from([64, 128, 256, 512]),
)

values = st.sampled_from(["normal", "outliers", "tiny", "mixed"])


def _gen(shape, kind, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    if kind == "outliers":
        mask = rng.random(size=shape) < 0.01
        x = np.where(mask, x * 100.0, x)
    elif kind == "tiny":
        x = x * 1e-5
    elif kind == "mixed":
        x[: shape[0] // 2] *= 50.0
    return jnp.asarray(x)


@settings(max_examples=25, deadline=None)
@given(mk=dims_mk, kind=values, seed=st.integers(0, 2**31 - 1))
def test_quant_act_property(mk, kind, seed):
    m, k = mk
    x = _gen((m, k), kind, seed)
    xq, xs = quant_act(x)
    xq_r, xs_r = ref.quant_act(x)
    np.testing.assert_array_equal(np.asarray(xq), np.asarray(xq_r))
    np.testing.assert_allclose(np.asarray(xs), np.asarray(xs_r), rtol=1e-6)
    # Invariant: |q| <= 127 always.
    assert np.abs(np.asarray(xq)).max() <= 127


@settings(max_examples=20, deadline=None)
@given(mk=dims_mk, n=st.sampled_from([64, 128, 256]), kind=values,
       seed=st.integers(0, 2**31 - 1))
def test_w8a8_property(mk, n, kind, seed):
    m, k = mk
    x = _gen((m, k), kind, seed)
    w = _gen((k, n), "normal", seed ^ 0xABCD)
    xq, xs = ref.quant_act(x)
    wq, ws = ref.quant_weight_int8(w)
    out = w8a8_gemm(xq, xs, wq, ws)
    out_r = ref.w8a8_matmul(xq, xs, wq, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(mk=dims_mk, n=st.sampled_from([64, 128, 256]), kind=values,
       seed=st.integers(0, 2**31 - 1))
def test_w4a8_property(mk, n, kind, seed):
    m, k = mk
    x = _gen((m, k), kind, seed)
    w = _gen((k, n), "normal", seed ^ 0x1234)
    xq, xs = ref.quant_act(x)
    wq, ws = ref.quant_weight_int4(w)
    packed = ref.pack_int4(wq)
    out = w4a8_gemm(xq, xs, packed, ws)
    out_r = ref.w4a8_matmul(xq, xs, packed, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 130), d=st.sampled_from([64, 128, 256, 512]),
       kind=values, seed=st.integers(0, 2**31 - 1))
def test_hadamard_property(m, d, kind, seed):
    x = _gen((m, d), kind, seed)
    out = hadamard(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.hadamard(x)),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=30, deadline=None)
@given(k=st.sampled_from([64, 128, 256]), n=st.sampled_from([32, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_pack_roundtrip_property(k, n, seed):
    rng = np.random.default_rng(seed)
    wq = jnp.asarray(rng.integers(-8, 8, size=(k, n), dtype=np.int8))
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_int4(ref.pack_int4(wq))), np.asarray(wq)
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), alpha=st.floats(0.25, 0.75))
def test_smooth_equivalence_property(seed, alpha):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    act_amax = jnp.max(jnp.abs(x), axis=0)
    s = ref.smooth_scales(act_amax, w, alpha)
    y_s = (np.asarray(x) / np.asarray(s)) @ np.asarray(ref.fold_smooth(w, s))
    np.testing.assert_allclose(y_s, np.asarray(x) @ np.asarray(w),
                               rtol=1e-3, atol=1e-3)
