"""PTEN format + HLO lowering tests (the Python<->Rust interchange)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.artifactio import lower_to_hlo_text, read_pten, write_pten


def test_pten_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = [
        ("embed", rng.normal(size=(64, 32)).astype(np.float32)),
        ("layers.0.wq.wq", rng.integers(-127, 128, size=(32, 32), dtype=np.int8)),
        ("scalarish", np.array([3], np.int32)),
        ("vec", rng.normal(size=(7,)).astype(np.float32)),
    ]
    p = tmp_path / "w.pten"
    write_pten(p, tensors)
    back = read_pten(p)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, back):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_pten_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(ValueError):
        write_pten(tmp_path / "bad.pten", [("x", np.zeros(3, np.float64))])


def test_pten_empty(tmp_path):
    p = tmp_path / "empty.pten"
    write_pten(p, [])
    assert read_pten(p) == []


def test_lower_produces_parseable_hlo():
    def fn(x, y):
        return (x @ y).sum(axis=0)

    spec = jnp.zeros((4, 4), jnp.float32)
    text = lower_to_hlo_text(fn, (spec, spec))
    assert "HloModule" in text
    assert "parameter(0)" in text and "parameter(1)" in text
    # return_tuple=False + single output: no tuple ROOT in entry.
    entry_root = [l for l in text.splitlines() if "ROOT" in l][-1]
    assert "tuple(" not in entry_root


def test_lower_int8_params_typed():
    def fn(wq, x):
        return x @ wq.astype(jnp.float32)

    text = lower_to_hlo_text(
        fn, (jnp.zeros((8, 8), jnp.int8), jnp.zeros((2, 8), jnp.float32))
    )
    assert "s8[8,8]" in text
